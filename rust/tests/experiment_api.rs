//! Integration tests for the unified experiment API: the `ScheduleSpec`
//! registry, the `Experiment`/`RunSpec` grid layer, the checked-in
//! `configs/*.json` presets, and the equivalence between the config-driven
//! path and the legacy figure subcommands.

use std::path::PathBuf;

use tokenring::config::ExperimentConfig;
use tokenring::experiment::{render, Experiment, RunSpec};
use tokenring::model::ModelConfig;
use tokenring::parallelism::partition::Partition;
use tokenring::parallelism::{AttnJob, Schedule, ScheduleSpec};
use tokenring::util::json::Json;

fn spec(schedule: ScheduleSpec, cluster: &str, devices: usize) -> RunSpec {
    RunSpec {
        schedule,
        cluster: cluster.to_string(),
        model: ModelConfig::llama2_7b(),
        seq: 4096,
        devices,
        causal: false,
        partition: Partition::Contiguous,
    }
}

#[test]
fn registry_round_trips_through_parse() {
    for s in ScheduleSpec::all() {
        assert_eq!(ScheduleSpec::parse(s.name()).unwrap(), s, "{}", s.name());
    }
    // names are unique
    let names: Vec<&str> = ScheduleSpec::all().iter().map(ScheduleSpec::name).collect();
    let mut dedup = names.clone();
    dedup.sort_unstable();
    dedup.dedup();
    assert_eq!(dedup.len(), names.len(), "duplicate registry names: {names:?}");
}

#[test]
fn every_spec_simulates_on_every_preset() {
    // Full-mesh presets support every registered schedule; the hybrid
    // additionally exercises its two-level home below.
    for (cluster, devices) in [("a10_pcie4", 4usize), ("oam_mesh", 8), ("nvswitch", 8)] {
        for schedule in ScheduleSpec::all() {
            let rec = spec(schedule, cluster, devices)
                .execute()
                .unwrap_or_else(|e| panic!("{} on {cluster}: {e}", schedule.name()));
            assert!(
                rec.makespan.is_finite() && rec.makespan > 0.0,
                "{} on {cluster}: makespan={}",
                schedule.name(),
                rec.makespan
            );
            assert_eq!(rec.schedule, schedule.name());
            assert_eq!(rec.cluster, cluster);
        }
    }
    // two_level (non-full-mesh): the hybrid's native topology
    let rec = spec(ScheduleSpec::Hybrid { nodes: 2, per_node: 4 }, "two_level", 8)
        .execute()
        .unwrap();
    assert!(rec.makespan.is_finite() && rec.makespan > 0.0);
}

#[test]
fn experiment_path_matches_direct_simulation() {
    // The RunSpec layer must not perturb the numbers: executing through
    // the experiment API gives exactly the makespan of building and
    // simulating the schedule by hand on the same preset.
    for schedule in [
        ScheduleSpec::TokenRing { elide_q: true },
        ScheduleSpec::RingAttention,
        ScheduleSpec::Ulysses,
        ScheduleSpec::TensorParallel,
    ] {
        let s = spec(schedule, "oam_mesh", 8);
        let rec = s.execute().unwrap();
        let cluster = tokenring::config::Cluster::by_name("oam_mesh", 8).unwrap();
        let job = AttnJob {
            shape: s.model.attn_shape(s.seq),
            compute: cluster.compute,
            causal: s.causal,
            partition: s.partition,
        };
        let direct = schedule.build().simulate(&cluster.topology, &job).makespan;
        assert_eq!(rec.makespan, direct, "{}", schedule.name());
    }
}

fn config_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs").join(name)
}

#[test]
fn checked_in_configs_load_and_expand() {
    for name in ["fig6.json", "table1.json", "oam_scaling.json"] {
        let text = std::fs::read_to_string(config_path(name))
            .unwrap_or_else(|e| panic!("reading {name}: {e}"));
        let cfg = ExperimentConfig::from_json(&text)
            .unwrap_or_else(|e| panic!("parsing {name}: {e}"));
        // loader round-trip: parse → serialize → parse is the identity
        let again = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(again, cfg, "{name} does not round-trip");
        let exp = Experiment::from_config(&cfg)
            .unwrap_or_else(|e| panic!("resolving {name}: {e}"));
        let specs = exp.expand().unwrap_or_else(|e| panic!("expanding {name}: {e}"));
        assert!(!specs.is_empty(), "{name} expands to an empty grid");
    }
}

#[test]
fn config_driven_fig6_matches_legacy_report() {
    // The acceptance bar: `tokenring run --config configs/fig6.json`
    // reproduces the legacy subcommand's numbers. Both paths share one
    // experiment layer; prove it at a test-sized sequence (the CLI's
    // `--seq` override).
    let text = std::fs::read_to_string(config_path("fig6.json")).unwrap();
    let cfg = ExperimentConfig::from_json(&text).unwrap();
    let mut exp = Experiment::from_config(&cfg).unwrap();
    exp.seqs = vec![4096];
    let recs = exp.run().unwrap();
    assert_eq!(recs.len(), 2);

    let (_, tr, ra) = tokenring::reports::fig6(4096).unwrap();
    assert_eq!(recs[0].schedule, "token_ring");
    assert_eq!(recs[0].makespan, tr.makespan);
    assert_eq!(recs[1].schedule, "ring_attention");
    assert_eq!(recs[1].makespan, ra.makespan);
}

#[test]
fn config_driven_table1_matches_legacy_report() {
    let text = std::fs::read_to_string(config_path("table1.json")).unwrap();
    let cfg = ExperimentConfig::from_json(&text).unwrap();
    let mut exp = Experiment::from_config(&cfg).unwrap();
    exp.seqs = vec![4096];
    let recs = exp.run().unwrap();
    assert_eq!(recs.len(), 4);

    // the volumes renderer used by `run --config` contains the same rows
    // the table1 subcommand prints
    let table = render::volumes_table(&recs);
    let (legacy, vols) = tokenring::reports::table1(4096, 4).unwrap();
    let _ = legacy;
    for (rec, vol) in recs.iter().zip(&vols) {
        assert_eq!(rec.volume.as_ref().unwrap().scheme, vol.scheme);
        assert_eq!(rec.volume.as_ref().unwrap().total_tx, vol.total_tx);
        assert!(table.contains(vol.scheme));
    }
}

#[test]
fn artifact_written_and_parses() {
    let recs = Experiment::new("artifact_test").seqs(&[4096]).run().unwrap();
    let dir = std::env::temp_dir().join("tokenring_experiment_api_test");
    let path = dir.join("runs.json");
    let _ = std::fs::remove_dir_all(&dir);
    render::write_json(&path, &recs).unwrap();
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let arr = j.get("records").as_arr().unwrap();
    assert_eq!(arr.len(), 1);
    assert_eq!(arr[0].get("schedule").as_str(), Some("token_ring"));
    assert_eq!(arr[0].get("seq").as_usize(), Some(4096));
    let _ = std::fs::remove_dir_all(&dir);
}
