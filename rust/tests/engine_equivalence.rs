//! Integration: the distributed engines (threads + channels + real
//! numerics) must reproduce single-device full attention exactly, for every
//! schedule × partition × backend combination — including the PJRT-artifact
//! backend, which exercises jax/pallas-lowered HLO inside each device
//! thread.

use tokenring::attention::full_attention;
use tokenring::engine::backend::BackendSpec;
use tokenring::engine::{run_hybrid, run_ring_attention, run_token_ring, EngineOpts};
use tokenring::parallelism::partition::Partition;
use tokenring::runtime::default_artifact_dir;
use tokenring::tensor::Tensor;
use tokenring::util::rng::Rng;

fn rand_qkv(seq: usize, h: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    let n = seq * h * d;
    (
        Tensor::new(&[seq, h, d], rng.normal_vec(n, 1.0)),
        Tensor::new(&[seq, h, d], rng.normal_vec(n, 1.0)),
        Tensor::new(&[seq, h, d], rng.normal_vec(n, 1.0)),
    )
}

fn have_artifacts() -> bool {
    default_artifact_dir().join("manifest.json").exists()
}

/// tiny-profile dims: 4 devices × 64-token blocks, H=4, D=32.
const TINY: (usize, usize, usize, usize) = (256, 4, 32, 4);

#[test]
fn pjrt_token_ring_matches_oracle_contiguous_and_zigzag() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (seq, h, d, n) = TINY;
    let (q, k, v) = rand_qkv(seq, h, d, 100);
    let (eo, el) = full_attention(&q, &k, &v, true);
    for partition in [Partition::Contiguous, Partition::Zigzag] {
        let opts = EngineOpts {
            causal: true,
            partition,
            backend: BackendSpec::Pjrt {
                dir: default_artifact_dir(),
                profile: "tiny".into(),
            },
            record: true,
            ..Default::default()
        };
        let got = run_token_ring(&q, &k, &v, n, &opts).unwrap();
        assert!(
            got.out.allclose(&eo, 1e-3),
            "{partition:?} out diff={}",
            got.out.max_abs_diff(&eo)
        );
        assert!(
            got.lse.allclose(&el, 1e-3),
            "{partition:?} lse diff={}",
            got.lse.max_abs_diff(&el)
        );
    }
}

#[test]
fn pjrt_ring_attention_matches_oracle() {
    if !have_artifacts() {
        return;
    }
    let (seq, h, d, n) = TINY;
    let (q, k, v) = rand_qkv(seq, h, d, 101);
    let opts = EngineOpts {
        causal: true,
        partition: Partition::Zigzag,
        backend: BackendSpec::Pjrt { dir: default_artifact_dir(), profile: "tiny".into() },
        record: false,
        ..Default::default()
    };
    let got = run_ring_attention(&q, &k, &v, n, &opts).unwrap();
    let (eo, el) = full_attention(&q, &k, &v, true);
    assert!(got.out.allclose(&eo, 1e-3), "diff={}", got.out.max_abs_diff(&eo));
    assert!(got.lse.allclose(&el, 1e-3));
}

#[test]
fn pjrt_noncausal_dit_case() {
    // Case study I: non-causal (DiT-style) attention through the full
    // artifact (attn_full_tiny).
    if !have_artifacts() {
        return;
    }
    let (seq, h, d, n) = TINY;
    let (q, k, v) = rand_qkv(seq, h, d, 102);
    let opts = EngineOpts {
        causal: false,
        partition: Partition::Contiguous,
        backend: BackendSpec::Pjrt { dir: default_artifact_dir(), profile: "tiny".into() },
        record: false,
        ..Default::default()
    };
    let got = run_token_ring(&q, &k, &v, n, &opts).unwrap();
    let (eo, el) = full_attention(&q, &k, &v, false);
    assert!(got.out.allclose(&eo, 1e-3), "diff={}", got.out.max_abs_diff(&eo));
    assert!(got.lse.allclose(&el, 1e-3));
}

#[test]
fn native_and_pjrt_backends_agree() {
    if !have_artifacts() {
        return;
    }
    let (seq, h, d, n) = TINY;
    let (q, k, v) = rand_qkv(seq, h, d, 103);
    let native = run_token_ring(
        &q,
        &k,
        &v,
        n,
        &EngineOpts {
            causal: true,
            partition: Partition::Zigzag,
            backend: BackendSpec::Native,
            record: false,
            ..Default::default()
        },
    )
    .unwrap();
    let pjrt = run_token_ring(
        &q,
        &k,
        &v,
        n,
        &EngineOpts {
            causal: true,
            partition: Partition::Zigzag,
            backend: BackendSpec::Pjrt { dir: default_artifact_dir(), profile: "tiny".into() },
            record: false,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(
        native.out.allclose(&pjrt.out, 1e-4),
        "backend divergence {}",
        native.out.max_abs_diff(&pjrt.out)
    );
}

#[test]
fn hybrid_multi_node_native() {
    // 2 nodes × 4 devices, zigzag causal — the full case-study-III path.
    let (q, k, v) = rand_qkv(128, 2, 16, 104);
    let opts = EngineOpts {
        causal: true,
        partition: Partition::Zigzag,
        backend: BackendSpec::Native,
        record: true,
        ..Default::default()
    };
    let got = run_hybrid(&q, &k, &v, 2, 4, &opts).unwrap();
    let (eo, el) = full_attention(&q, &k, &v, true);
    assert!(got.out.allclose(&eo, 1e-4), "diff={}", got.out.max_abs_diff(&eo));
    assert!(got.lse.allclose(&el, 1e-3));
    // hybrid KV rotation happened: SendKv events present
    use tokenring::simulator::SpanTag;
    let kv_sends = got
        .timeline
        .events
        .iter()
        .filter(|e| e.tag == SpanTag::SendKv)
        .count();
    assert_eq!(kv_sends, 8); // one per device per (nodes-1) outer boundary
}

#[test]
fn stress_many_degrees_native() {
    for n in [2usize, 4, 8, 16] {
        let (q, k, v) = rand_qkv(32 * n, 2, 8, 200 + n as u64);
        let opts = EngineOpts {
            causal: true,
            partition: Partition::Zigzag,
            backend: BackendSpec::Native,
            record: false,
            ..Default::default()
        };
        let got = run_token_ring(&q, &k, &v, n, &opts).unwrap();
        let (eo, _) = full_attention(&q, &k, &v, true);
        assert!(got.out.allclose(&eo, 1e-4), "n={n} diff={}", got.out.max_abs_diff(&eo));
    }
}

#[test]
fn repeated_runs_are_consistent() {
    let (q, k, v) = rand_qkv(64, 2, 16, 300);
    let opts = EngineOpts {
        causal: true,
        partition: Partition::Zigzag,
        backend: BackendSpec::Native,
        record: false,
        ..Default::default()
    };
    let a = run_token_ring(&q, &k, &v, 4, &opts).unwrap();
    let b = run_token_ring(&q, &k, &v, 4, &opts).unwrap();
    // merge order can vary between runs (async arrivals) but the result
    // must stay within tolerance — the order-invariance property.
    assert!(a.out.allclose(&b.out, 1e-5));
    assert!(a.lse.allclose(&b.lse, 1e-5));
}

#[test]
fn gqa_token_ring_matches_oracle_native_and_pjrt() {
    // GQA: 4 query heads sharing 2 KV heads — the regime where Ulysses'
    // degree cap bites but TokenRing is unaffected.
    let (seq, n) = (256usize, 4usize);
    let mut rng = Rng::new(400);
    let q = Tensor::new(&[seq, 4, 32], rng.normal_vec(seq * 4 * 32, 1.0));
    let k = Tensor::new(&[seq, 2, 32], rng.normal_vec(seq * 2 * 32, 1.0));
    let v = Tensor::new(&[seq, 2, 32], rng.normal_vec(seq * 2 * 32, 1.0));
    let (eo, el) = tokenring::attention::attention_block(
        &q,
        &k,
        &v,
        &(0..seq as i32).collect::<Vec<_>>(),
        &(0..seq as i32).collect::<Vec<_>>(),
        true,
        None,
    );
    let mut backends = vec![BackendSpec::Native];
    if have_artifacts() {
        backends.push(BackendSpec::Pjrt {
            dir: default_artifact_dir(),
            profile: "gqa_tiny".into(),
        });
    }
    for backend in backends {
        let opts = EngineOpts {
            causal: true,
            partition: Partition::Zigzag,
            backend,
            record: false,
            ..Default::default()
        };
        let got = run_token_ring(&q, &k, &v, n, &opts).unwrap();
        assert!(
            got.out.allclose(&eo, 1e-3),
            "gqa out diff={}",
            got.out.max_abs_diff(&eo)
        );
        assert!(got.lse.allclose(&el, 1e-3));
    }
}
