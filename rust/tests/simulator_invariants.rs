//! Property tests (hand-rolled, deterministic PRNG — no proptest offline)
//! on the discrete-event simulator and the schedule builders:
//!
//! * resources never overlap two tasks in time
//! * span ordering respects the dependency DAG
//! * schedules conserve compute work regardless of topology
//! * merge-rule algebra: order invariance over random partitions

use tokenring::attention::{attention_block, full_attention, merge_into};
use tokenring::comm::{AttnShape, ComputeModel, Dtype};
use tokenring::parallelism::partition::Partition;
use tokenring::parallelism::ring_attention::RingAttention;
use tokenring::parallelism::token_ring::TokenRing;
use tokenring::parallelism::{AttnJob, Schedule};
use tokenring::simulator::{simulate, ResourceId, SimResult};
use tokenring::tensor::Tensor;
use tokenring::topology::Topology;
use tokenring::util::rng::Rng;

fn random_job(rng: &mut Rng) -> (AttnJob, Topology) {
    let n = *rng.choose(&[2usize, 4, 8]);
    let blk = *rng.choose(&[512usize, 1024, 2048]);
    let heads = *rng.choose(&[8usize, 16, 32]);
    let job = AttnJob {
        shape: AttnShape::new(blk * n, heads, 128, Dtype::F16),
        compute: ComputeModel {
            peak_flops: rng.uniform_range(1e13, 2e14),
            efficiency: rng.uniform_range(0.3, 0.9),
            launch_overhead: 10e-6,
        },
        causal: rng.uniform() < 0.5,
        partition: *rng.choose(&[Partition::Contiguous, Partition::Zigzag]),
    };
    let topo = match rng.below(3) {
        0 => Topology::oam_mesh(n, rng.uniform_range(50.0, 600.0)),
        1 => Topology::nvswitch(n, rng.uniform_range(20.0, 300.0)),
        _ => Topology::uniform_mesh(n, rng.uniform_range(5.0, 100.0)),
    };
    (job, topo)
}

/// No resource may run two tasks at once.
fn check_no_resource_overlap(r: &SimResult) {
    let mut by_resource: std::collections::HashMap<ResourceId, Vec<(f64, f64)>> =
        std::collections::HashMap::new();
    for s in &r.spans {
        for res in &r.graph.tasks[s.task].resources {
            by_resource.entry(*res).or_default().push((s.start, s.end));
        }
    }
    for (res, mut spans) in by_resource {
        spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in spans.windows(2) {
            assert!(
                w[1].0 >= w[0].1 - 1e-12,
                "resource {res:?} overlaps: {w:?}"
            );
        }
    }
}

/// Every task starts only after all its deps ended.
fn check_dependencies(r: &SimResult) {
    let end: std::collections::HashMap<usize, f64> =
        r.spans.iter().map(|s| (s.task, s.end)).collect();
    for s in &r.spans {
        for &d in &r.graph.tasks[s.task].deps {
            assert!(
                s.start >= end[&d] - 1e-12,
                "task {} started before dep {}",
                s.task,
                d
            );
        }
    }
}

#[test]
fn simulator_invariants_random_schedules() {
    let mut rng = Rng::new(0xBEEF);
    for trial in 0..30 {
        let (job, topo) = random_job(&mut rng);
        for sched in [&TokenRing::default() as &dyn Schedule, &RingAttention] {
            let r = sched.simulate(&topo, &job);
            assert!(r.makespan.is_finite() && r.makespan > 0.0, "trial {trial}");
            check_no_resource_overlap(&r);
            check_dependencies(&r);
            // every task ran exactly once
            assert_eq!(r.spans.len(), r.graph.len());
        }
    }
}

#[test]
fn schedules_conserve_compute_work() {
    // Total compute-busy seconds must be identical for TokenRing and
    // Ring-Attention (same blocks computed, different transport), on any
    // topology.
    let mut rng = Rng::new(0xF00D);
    for _ in 0..10 {
        let (job, topo) = random_job(&mut rng);
        let tr = TokenRing::default().simulate(&topo, &job);
        let ra = RingAttention.simulate(&topo, &job);
        let tr_busy = tr.total_compute_busy();
        let ra_busy = ra.total_compute_busy();
        assert!(
            (tr_busy - ra_busy).abs() / tr_busy < 1e-9,
            "work not conserved: {tr_busy} vs {ra_busy}"
        );
    }
}

#[test]
fn makespan_monotone_in_bandwidth() {
    // Faster links can never make a schedule slower.
    let job = AttnJob {
        shape: AttnShape::new(16_384, 16, 128, Dtype::F16),
        compute: ComputeModel::a10(0.5),
        causal: false,
        partition: Partition::Contiguous,
    };
    let mut prev = f64::INFINITY;
    for gbps in [5.0, 10.0, 20.0, 40.0, 80.0] {
        let topo = Topology::uniform_mesh(4, gbps);
        let m = TokenRing::default().simulate(&topo, &job).makespan;
        assert!(m <= prev + 1e-12, "makespan rose with bandwidth: {m} > {prev}");
        prev = m;
    }
}

#[test]
fn merge_order_invariance_random_partitions() {
    // The algebraic property TokenRing relies on, over random block counts,
    // shapes and merge orders (native kernels).
    let mut rng = Rng::new(0xABCD);
    for _ in 0..15 {
        let h = rng.range(1, 3);
        let d = 8 * rng.range(1, 3);
        let sq = 16 * rng.range(1, 3);
        let nb = rng.range(2, 5);
        let skv = 16 * rng.range(1, 3);
        let total_kv = nb * skv;

        let q = Tensor::new(&[sq, h, d], rng.normal_vec(sq * h * d, 1.0));
        let k = Tensor::new(&[total_kv, h, d], rng.normal_vec(total_kv * h * d, 1.0));
        let v = Tensor::new(&[total_kv, h, d], rng.normal_vec(total_kv * h * d, 1.0));
        let q_pos: Vec<i32> = (total_kv as i32..(total_kv + sq) as i32).collect();
        let k_pos: Vec<i32> = (0..total_kv as i32).collect();

        let parts: Vec<(Tensor, Tensor)> = (0..nb)
            .map(|b| {
                attention_block(
                    &q,
                    &k.slice_rows(b * skv, (b + 1) * skv),
                    &v.slice_rows(b * skv, (b + 1) * skv),
                    &q_pos,
                    &k_pos[b * skv..(b + 1) * skv],
                    true,
                    None,
                )
            })
            .collect();

        let mut order: Vec<usize> = (0..nb).collect();
        rng.shuffle(&mut order);
        let (mut out, mut lse) = parts[order[0]].clone();
        for &i in &order[1..] {
            merge_into(&mut out, &mut lse, &parts[i].0, &parts[i].1);
        }

        let qk = Tensor::concat_rows(&[&q]);
        let _ = qk;
        // reference: full attention over concatenated kv with the same
        // positions
        let (eo, el) = attention_block(&q, &k, &v, &q_pos, &k_pos, true, None);
        assert!(
            out.allclose(&eo, 1e-4),
            "order {order:?} diff={}",
            out.max_abs_diff(&eo)
        );
        assert!(lse.allclose(&el, 1e-3));
    }
}

#[test]
fn full_attention_agrees_with_blockwise_any_split() {
    let mut rng = Rng::new(0x5EED);
    for _ in 0..10 {
        let s = 32 * rng.range(1, 4);
        let h = rng.range(1, 3);
        let d = 8;
        let q = Tensor::new(&[s, h, d], rng.normal_vec(s * h * d, 1.0));
        let k = Tensor::new(&[s, h, d], rng.normal_vec(s * h * d, 1.0));
        let v = Tensor::new(&[s, h, d], rng.normal_vec(s * h * d, 1.0));
        let (eo, _) = full_attention(&q, &k, &v, true);

        // split kv at a random point, compute + merge
        let cut = 8 * rng.range(1, s / 8 - 1).max(1);
        let pos: Vec<i32> = (0..s as i32).collect();
        let (mut o, mut l) = attention_block(
            &q,
            &k.slice_rows(0, cut),
            &v.slice_rows(0, cut),
            &pos,
            &pos[..cut],
            true,
            None,
        );
        let (bo, bl) = attention_block(
            &q,
            &k.slice_rows(cut, s),
            &v.slice_rows(cut, s),
            &pos,
            &pos[cut..],
            true,
            None,
        );
        merge_into(&mut o, &mut l, &bo, &bl);
        assert!(o.allclose(&eo, 1e-4), "cut={cut} diff={}", o.max_abs_diff(&eo));
    }
}
