//! Chaos acceptance tests for the fault-tolerant serve loop.
//!
//! The contract under test (ISSUE 7): with a deterministic [`FaultPlan`]
//! injecting a single fault — an actor panic, a dropped or corrupted KV
//! delta, or a reply stall past the watchdog — the continuous batcher
//! recovers by respawning the ring and replaying every resident request,
//! and every request still completes with an `output_digest` equal to the
//! fault-free run's (1e-3). Transient stalls inside the retry budget must
//! be absorbed without a recovery; exhausting `max_recoveries` must fail
//! the remaining requests gracefully (per-request `Failed` status, not a
//! process-level `Err`).

use tokenring::engine::faults::FaultPlan;
use tokenring::scheduler::{serve_continuous, ContinuousServeOpts, RequestStatus};
use tokenring::workload::{Priority, Request};

/// Two-device actors-runtime serve session, small enough that every fault
/// kind lands within ~8 micro-steps.
fn opts() -> ContinuousServeOpts {
    ContinuousServeOpts {
        devices: 2,
        heads: 2,
        head_dim: 8,
        chunk: 16,
        max_batch: 8,
        max_step_tokens: 512,
        kv_budget_tokens: 1 << 20,
        aging_steps: 16,
        seed: 42,
        ..Default::default()
    }
}

fn requests() -> Vec<Request> {
    (0..6)
        .map(|id| Request {
            id,
            seq_len: 32 + 16 * (id % 3),
            arrival: 0.0,
            decode_tokens: 4,
            priority: Priority::Standard,
            prefix: None,
        })
        .collect()
}

/// Per-request digests in id order (the report sorts by id).
fn digests(report: &tokenring::scheduler::ContinuousServeReport) -> Vec<f64> {
    report.requests.iter().map(|r| r.output_digest).collect()
}

fn assert_digests_match(got: &[f64], want: &[f64], label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: request count");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            (a - b).abs() < 1e-3,
            "{label}: request {i} digest diverges from the fault-free run \
             ({a} vs {b})"
        );
    }
}

fn assert_all_completed(report: &tokenring::scheduler::ContinuousServeReport, label: &str) {
    assert_eq!(report.requests.len(), 6, "{label}: every request reported");
    for r in &report.requests {
        assert_eq!(
            r.status,
            RequestStatus::Completed,
            "{label}: request {} did not complete",
            r.id
        );
        assert_eq!(r.decode_tokens, 4, "{label}: request {} decode count", r.id);
    }
}

#[test]
fn fault_free_baseline_is_clean() {
    let report = serve_continuous(&requests(), &opts()).unwrap();
    assert_all_completed(&report, "baseline");
    assert!(
        report.faults.is_clean(),
        "no injector → zero fault accounting: {:?}",
        report.faults
    );
    for r in &report.requests {
        assert!(r.output_digest > 0.0, "request {} produced no digest", r.id);
    }
}

#[test]
fn single_faults_recover_to_fault_free_digests() {
    let baseline = digests(&serve_continuous(&requests(), &opts()).unwrap());
    // Each detectable fault kind at a boundary step (0: first appends /
    // first micro-step) and a mid-serve step, on both devices.
    for spec in ["panic@0:1", "panic@3:0", "drop@0:0", "drop@3:1", "corrupt@0:0", "corrupt@3:1"] {
        let mut o = opts();
        o.faults = Some(FaultPlan::parse(spec).unwrap());
        let report = serve_continuous(&requests(), &o)
            .unwrap_or_else(|e| panic!("{spec}: serve must recover, got Err: {e:#}"));
        assert_all_completed(&report, spec);
        assert!(
            report.faults.faults_injected >= 1,
            "{spec}: the planned fault never fired ({:?})",
            report.faults
        );
        assert!(
            report.faults.recoveries >= 1,
            "{spec}: fault absorbed without a ring recovery ({:?})",
            report.faults
        );
        assert!(report.faults.failure.is_none(), "{spec}: session must not fail");
        // boundary (step-0) faults can poison the ring before any request
        // records progress, so replay accounting is only asserted mid-serve
        if spec.contains("@3") {
            assert!(report.faults.replayed_tokens > 0, "{spec}: recovery must replay work");
        }
        assert_digests_match(&digests(&report), &baseline, spec);
    }
}

#[test]
fn transient_stall_is_absorbed_by_watchdog_retries() {
    let baseline = digests(&serve_continuous(&requests(), &opts()).unwrap());
    let mut o = opts();
    // 100ms stall against 30ms + doubled-wait retries (30+60+120+... ms of
    // patience): the reply lands inside the retry budget, so the watchdog
    // extends instead of escalating.
    o.faults = Some(FaultPlan::parse("stall@2:1:100").unwrap());
    o.watchdog_ms = 30;
    o.max_retries = 4;
    let report = serve_continuous(&requests(), &o).unwrap();
    assert_all_completed(&report, "transient stall");
    assert!(report.faults.faults_injected >= 1, "stall never fired");
    assert!(
        report.faults.watchdog_retries >= 1,
        "a 100ms stall must trip the 30ms watchdog at least once ({:?})",
        report.faults
    );
    assert_eq!(
        report.faults.recoveries, 0,
        "a stall inside the retry budget must not tear the ring down"
    );
    assert_digests_match(&digests(&report), &baseline, "transient stall");
}

#[test]
fn stall_past_the_retry_budget_escalates_to_recovery() {
    let baseline = digests(&serve_continuous(&requests(), &opts()).unwrap());
    let mut o = opts();
    // 400ms stall against 10ms + one retry (30ms of patience): the
    // watchdog exhausts, the ring is torn down, and replay completes the
    // session on a fresh ring.
    o.faults = Some(FaultPlan::parse("stall@2:1:400").unwrap());
    o.watchdog_ms = 10;
    o.max_retries = 1;
    let report = serve_continuous(&requests(), &o).unwrap();
    assert_all_completed(&report, "stall escalation");
    assert!(report.faults.recoveries >= 1, "escalation must respawn the ring");
    assert!(report.faults.failure.is_none());
    assert_digests_match(&digests(&report), &baseline, "stall escalation");
}

#[test]
fn multi_fault_plan_fires_every_slot_once() {
    let baseline = digests(&serve_continuous(&requests(), &opts()).unwrap());
    let mut o = opts();
    // A panic early plus a survivable stall later: the shared injector
    // must keep its session-wide step count across the respawn and never
    // re-fire the consumed panic slot during replay.
    o.faults = Some(FaultPlan::parse("panic@1:0,stall@5:1:100").unwrap());
    o.watchdog_ms = 40;
    o.max_retries = 3;
    let report = serve_continuous(&requests(), &o).unwrap();
    assert_all_completed(&report, "multi-fault");
    assert_eq!(report.faults.faults_injected, 2, "both planned faults fire exactly once");
    assert!(report.faults.recoveries >= 1);
    assert!(report.faults.failure.is_none());
    assert_digests_match(&digests(&report), &baseline, "multi-fault");
}

#[test]
fn degraded_recovery_still_matches_digests() {
    let baseline = digests(&serve_continuous(&requests(), &opts()).unwrap());
    let mut o = opts();
    o.faults = Some(FaultPlan::parse("panic@1:1").unwrap());
    o.degrade_on_recovery = true;
    let report = serve_continuous(&requests(), &o).unwrap();
    assert_all_completed(&report, "degraded recovery");
    assert!(report.faults.recoveries >= 1);
    // the respawned ring runs with one device fewer, but the attention
    // math is device-count-invariant, so the digests must not move
    assert_digests_match(&digests(&report), &baseline, "degraded recovery");
}

#[test]
fn exhausted_recovery_budget_fails_requests_gracefully() {
    let mut o = opts();
    o.faults = Some(FaultPlan::parse("panic@0:1").unwrap());
    o.max_recoveries = 0;
    let report = serve_continuous(&requests(), &o)
        .expect("budget exhaustion is a graceful per-request failure, not an Err");
    assert_eq!(report.requests.len(), 6, "failed requests still appear in the report");
    for r in &report.requests {
        assert_eq!(r.status, RequestStatus::Failed, "request {} should have failed", r.id);
        assert_eq!(r.output_digest, 0.0, "failed request {} must not claim output", r.id);
    }
    assert_eq!(report.faults.failed_requests, 6);
    assert!(
        report.faults.failure.is_some(),
        "the report must carry the terminal failure cause"
    );
    assert_eq!(report.faults.recoveries, 0, "budget 0 means no respawn attempts");
    // failed requests are excluded from the latency summaries
    assert_eq!(report.ttft_summary().n, 0);
    assert_eq!(report.tpot_summary().n, 0);
}
