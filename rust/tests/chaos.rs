//! Chaos acceptance tests for the fault-tolerant serve loop.
//!
//! The contract under test (ISSUE 7): with a deterministic [`FaultPlan`]
//! injecting a single fault — an actor panic, a dropped or corrupted KV
//! delta, or a reply stall past the watchdog — the continuous batcher
//! recovers by respawning the ring and replaying every resident request,
//! and every request still completes with an `output_digest` equal to the
//! fault-free run's (1e-3). Transient stalls inside the retry budget must
//! be absorbed without a recovery; exhausting `max_recoveries` must fail
//! the remaining requests gracefully (per-request `Failed` status, not a
//! process-level `Err`).

mod common;

use common::{digests, std_requests};
use tokenring::engine::faults::FaultPlan;
use tokenring::scheduler::{
    serve_continuous, serve_disagg, ContinuousServeOpts, DisaggOpts, PoolSplit, RequestStatus,
};
use tokenring::workload::Request;

/// Two-device actors-runtime serve session, small enough that every fault
/// kind lands within ~8 micro-steps.
fn opts() -> ContinuousServeOpts {
    common::serve_opts(2, 16)
}

fn requests() -> Vec<Request> {
    std_requests(6)
}

fn assert_digests_match(got: &[f64], want: &[f64], label: &str) {
    common::assert_digests_match(got, want, 1e-3, label);
}

fn assert_all_completed(report: &tokenring::scheduler::ContinuousServeReport, label: &str) {
    assert_eq!(report.requests.len(), 6, "{label}: every request reported");
    for r in &report.requests {
        assert_eq!(
            r.status,
            RequestStatus::Completed,
            "{label}: request {} did not complete",
            r.id
        );
        assert_eq!(r.decode_tokens, 4, "{label}: request {} decode count", r.id);
    }
}

#[test]
fn fault_free_baseline_is_clean() {
    let report = serve_continuous(&requests(), &opts()).unwrap();
    assert_all_completed(&report, "baseline");
    assert!(
        report.faults.is_clean(),
        "no injector → zero fault accounting: {:?}",
        report.faults
    );
    for r in &report.requests {
        assert!(r.output_digest > 0.0, "request {} produced no digest", r.id);
    }
}

#[test]
fn single_faults_recover_to_fault_free_digests() {
    let baseline = digests(&serve_continuous(&requests(), &opts()).unwrap());
    // Each detectable fault kind at a boundary step (0: first appends /
    // first micro-step) and a mid-serve step, on both devices.
    for spec in ["panic@0:1", "panic@3:0", "drop@0:0", "drop@3:1", "corrupt@0:0", "corrupt@3:1"] {
        let mut o = opts();
        o.faults = Some(FaultPlan::parse(spec).unwrap());
        let report = serve_continuous(&requests(), &o)
            .unwrap_or_else(|e| panic!("{spec}: serve must recover, got Err: {e:#}"));
        assert_all_completed(&report, spec);
        assert!(
            report.faults.faults_injected >= 1,
            "{spec}: the planned fault never fired ({:?})",
            report.faults
        );
        assert!(
            report.faults.recoveries >= 1,
            "{spec}: fault absorbed without a ring recovery ({:?})",
            report.faults
        );
        assert!(report.faults.failure.is_none(), "{spec}: session must not fail");
        // boundary (step-0) faults can poison the ring before any request
        // records progress, so replay accounting is only asserted mid-serve
        if spec.contains("@3") {
            assert!(report.faults.replayed_tokens > 0, "{spec}: recovery must replay work");
        }
        assert_digests_match(&digests(&report), &baseline, spec);
    }
}

#[test]
fn transient_stall_is_absorbed_by_watchdog_retries() {
    let baseline = digests(&serve_continuous(&requests(), &opts()).unwrap());
    let mut o = opts();
    // 100ms stall against 30ms + doubled-wait retries (30+60+120+... ms of
    // patience): the reply lands inside the retry budget, so the watchdog
    // extends instead of escalating.
    o.faults = Some(FaultPlan::parse("stall@2:1:100").unwrap());
    o.watchdog_ms = 30;
    o.max_retries = 4;
    let report = serve_continuous(&requests(), &o).unwrap();
    assert_all_completed(&report, "transient stall");
    assert!(report.faults.faults_injected >= 1, "stall never fired");
    assert!(
        report.faults.watchdog_retries >= 1,
        "a 100ms stall must trip the 30ms watchdog at least once ({:?})",
        report.faults
    );
    assert_eq!(
        report.faults.recoveries, 0,
        "a stall inside the retry budget must not tear the ring down"
    );
    assert_digests_match(&digests(&report), &baseline, "transient stall");
}

#[test]
fn stall_past_the_retry_budget_escalates_to_recovery() {
    let baseline = digests(&serve_continuous(&requests(), &opts()).unwrap());
    let mut o = opts();
    // 400ms stall against 10ms + one retry (30ms of patience): the
    // watchdog exhausts, the ring is torn down, and replay completes the
    // session on a fresh ring.
    o.faults = Some(FaultPlan::parse("stall@2:1:400").unwrap());
    o.watchdog_ms = 10;
    o.max_retries = 1;
    let report = serve_continuous(&requests(), &o).unwrap();
    assert_all_completed(&report, "stall escalation");
    assert!(report.faults.recoveries >= 1, "escalation must respawn the ring");
    assert!(report.faults.failure.is_none());
    assert_digests_match(&digests(&report), &baseline, "stall escalation");
}

#[test]
fn multi_fault_plan_fires_every_slot_once() {
    let baseline = digests(&serve_continuous(&requests(), &opts()).unwrap());
    let mut o = opts();
    // A panic early plus a survivable stall later: the shared injector
    // must keep its session-wide step count across the respawn and never
    // re-fire the consumed panic slot during replay.
    o.faults = Some(FaultPlan::parse("panic@1:0,stall@5:1:100").unwrap());
    o.watchdog_ms = 40;
    o.max_retries = 3;
    let report = serve_continuous(&requests(), &o).unwrap();
    assert_all_completed(&report, "multi-fault");
    assert_eq!(report.faults.faults_injected, 2, "both planned faults fire exactly once");
    assert!(report.faults.recoveries >= 1);
    assert!(report.faults.failure.is_none());
    assert_digests_match(&digests(&report), &baseline, "multi-fault");
}

#[test]
fn degraded_recovery_still_matches_digests() {
    let baseline = digests(&serve_continuous(&requests(), &opts()).unwrap());
    let mut o = opts();
    o.faults = Some(FaultPlan::parse("panic@1:1").unwrap());
    o.degrade_on_recovery = true;
    let report = serve_continuous(&requests(), &o).unwrap();
    assert_all_completed(&report, "degraded recovery");
    assert!(report.faults.recoveries >= 1);
    // the respawned ring runs with one device fewer, but the attention
    // math is device-count-invariant, so the digests must not move
    assert_digests_match(&digests(&report), &baseline, "degraded recovery");
}

// ---------------------------------------------------------------------------
// Disaggregated pools: fault isolation (ISSUE 10 satellite)
// ---------------------------------------------------------------------------

/// 3-device disaggregated session: 2-device prefill pool + 1-device
/// decode pool over the same workload the unified chaos tests use.
fn disagg_opts() -> (ContinuousServeOpts, DisaggOpts) {
    let split = PoolSplit::parse("2p+1d").unwrap().unwrap();
    (common::serve_opts(3, 16), DisaggOpts::new(split))
}

fn disagg_digests(o: &ContinuousServeOpts, d: &DisaggOpts, label: &str) -> Vec<f64> {
    let report = serve_disagg(&requests(), o, d)
        .unwrap_or_else(|e| panic!("{label}: serve must recover, got Err: {e:#}"));
    assert_all_completed(&report.core, label);
    assert!(report.core.faults.failure.is_none(), "{label}: session must not fail");
    digests(&report.core)
}

#[test]
fn prefill_pool_fault_does_not_disturb_decode_pool() {
    let (o, base) = disagg_opts();
    let baseline = disagg_digests(&o, &base, "disagg baseline");

    let mut d = base.clone();
    d.prefill_faults = Some(FaultPlan::parse("panic@1:0").unwrap());
    let report = serve_disagg(&requests(), &o, &d).unwrap();
    assert_all_completed(&report.core, "prefill-pool fault");
    assert!(
        report.prefill.faults.faults_injected >= 1,
        "the prefill-pool fault never fired ({:?})",
        report.prefill.faults
    );
    assert!(
        report.prefill.faults.recoveries >= 1,
        "prefill fault absorbed without a pool recovery ({:?})",
        report.prefill.faults
    );
    // isolation: the decode pool's ring never sees a fault or a respawn
    assert_eq!(report.decode.faults.faults_injected, 0, "decode pool saw a fault");
    assert_eq!(report.decode.faults.recoveries, 0, "decode pool respawned");
    assert_eq!(report.decode.faults.replayed_tokens, 0, "decode pool replayed work");
    assert_digests_match(&digests(&report.core), &baseline, "prefill-pool fault");
}

#[test]
fn decode_pool_fault_does_not_disturb_prefill_pool() {
    let (o, base) = disagg_opts();
    let baseline = disagg_digests(&o, &base, "disagg baseline");

    let mut d = base.clone();
    d.decode_faults = Some(FaultPlan::parse("panic@1:0").unwrap());
    let report = serve_disagg(&requests(), &o, &d).unwrap();
    assert_all_completed(&report.core, "decode-pool fault");
    assert!(report.decode.faults.faults_injected >= 1, "the decode-pool fault never fired");
    assert!(
        report.decode.faults.recoveries >= 1,
        "decode fault absorbed without a pool recovery ({:?})",
        report.decode.faults
    );
    assert_eq!(report.prefill.faults.faults_injected, 0, "prefill pool saw a fault");
    assert_eq!(report.prefill.faults.recoveries, 0, "prefill pool respawned");
    // a decode-pool respawn re-imports handed-off KV, so imports can
    // exceed the shipped total — but never undershoot it
    assert!(
        report.handoff.imported_tokens >= report.handoff.tokens,
        "re-imported {} of {} shipped tokens",
        report.handoff.imported_tokens,
        report.handoff.tokens
    );
    assert_digests_match(&digests(&report.core), &baseline, "decode-pool fault");
}

#[test]
fn base_fault_plan_routes_to_the_decode_pool() {
    // `opts.faults` (the unified knob, e.g. `--faults` without a pool
    // prefix) lands on the decode pool when no per-pool plan is set.
    let (mut o, d) = disagg_opts();
    let baseline = disagg_digests(&o, &d, "disagg baseline");
    o.faults = Some(FaultPlan::parse("panic@1:0").unwrap());
    let report = serve_disagg(&requests(), &o, &d).unwrap();
    assert_all_completed(&report.core, "base-plan routing");
    assert!(report.decode.faults.faults_injected >= 1, "base plan must hit the decode pool");
    assert_eq!(report.prefill.faults.faults_injected, 0);
    assert_digests_match(&digests(&report.core), &baseline, "base-plan routing");
}

#[test]
fn in_flight_handoffs_survive_simultaneous_pool_respawns() {
    // Panic both pools at their first ring step: the prefill pool
    // respawns with requests mid-prefill, the decode pool respawns with
    // landed handoffs resident and more still in flight. Every request
    // must re-queue, re-import its handed-off KV, and finish with the
    // fault-free digests.
    let (o, base) = disagg_opts();
    let baseline = disagg_digests(&o, &base, "disagg baseline");

    let mut d = base.clone();
    d.prefill_faults = Some(FaultPlan::parse("panic@0:1").unwrap());
    d.decode_faults = Some(FaultPlan::parse("panic@0:0").unwrap());
    let report = serve_disagg(&requests(), &o, &d).unwrap();
    assert_all_completed(&report.core, "dual-pool respawn");
    assert!(report.prefill.faults.recoveries >= 1, "prefill pool must respawn");
    assert!(report.decode.faults.recoveries >= 1, "decode pool must respawn");
    assert_eq!(
        report.core.faults.recoveries,
        report.prefill.faults.recoveries + report.decode.faults.recoveries,
        "combined accounting sums the pools"
    );
    // nothing is lost across the respawns: every prompt token still
    // arrives in the decode pool (re-imports may repeat a shipment)
    let prompt_tokens: usize = requests().iter().map(|r| r.seq_len).sum();
    assert_eq!(report.handoff.tokens, prompt_tokens, "every prompt ships exactly once");
    assert!(report.handoff.imported_tokens >= prompt_tokens);
    assert_digests_match(&digests(&report.core), &baseline, "dual-pool respawn");
}

#[test]
fn exhausted_decode_pool_budget_fails_requests_gracefully() {
    let (mut o, mut d) = disagg_opts();
    o.max_recoveries = 0;
    d.decode_faults = Some(FaultPlan::parse("panic@0:0").unwrap());
    let report = serve_disagg(&requests(), &o, &d)
        .expect("budget exhaustion is a graceful per-request failure, not an Err");
    assert_eq!(report.core.requests.len(), 6);
    for r in &report.core.requests {
        assert_eq!(r.status, RequestStatus::Failed, "request {} should have failed", r.id);
    }
    assert!(report.core.faults.failure.is_some(), "the report must carry the cause");
    assert_eq!(report.decode.faults.failed_requests, 6, "the decode pool owns the failure");
    assert_eq!(report.prefill.faults.failed_requests, 0);
}

#[test]
fn exhausted_recovery_budget_fails_requests_gracefully() {
    let mut o = opts();
    o.faults = Some(FaultPlan::parse("panic@0:1").unwrap());
    o.max_recoveries = 0;
    let report = serve_continuous(&requests(), &o)
        .expect("budget exhaustion is a graceful per-request failure, not an Err");
    assert_eq!(report.requests.len(), 6, "failed requests still appear in the report");
    for r in &report.requests {
        assert_eq!(r.status, RequestStatus::Failed, "request {} should have failed", r.id);
        assert_eq!(r.output_digest, 0.0, "failed request {} must not claim output", r.id);
    }
    assert_eq!(report.faults.failed_requests, 6);
    assert!(
        report.faults.failure.is_some(),
        "the report must carry the terminal failure cause"
    );
    assert_eq!(report.faults.recoveries, 0, "budget 0 means no respawn attempts");
    // failed requests are excluded from the latency summaries
    assert_eq!(report.ttft_summary().n, 0);
    assert_eq!(report.tpot_summary().n, 0);
}
