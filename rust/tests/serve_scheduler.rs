//! Acceptance tests for the continuous-batching serve subsystem:
//!
//! 1. The continuous batcher preserves per-request output equivalence
//!    with the sequential (one-at-a-time) serve path on identical request
//!    sets — while actually batching (>1 request in flight).
//! 2. Preemption respects the KV budget invariant: resident KV tokens
//!    never exceed the budget at any step, and evicted requests replay to
//!    the same outputs.
//! 3. Priority classes never starve FCFS traffic beyond the aging bound.
//! 4. The persistent actor-ring runtime and the legacy spawn-per-step
//!    runtime produce equivalent per-request outputs on every workload
//!    mix (the serve-runtime equivalence proof).

mod common;

use common::{mix_requests, req, serve_opts as opts};
use tokenring::scheduler::{serve_continuous, serve_sequential, ServeRuntime};
use tokenring::workload::{Priority, Request, ServeMix};

#[test]
fn continuous_matches_sequential_outputs() {
    let requests: Vec<Request> = (0..6)
        .map(|id| req(id, 32 + 16 * (id % 3), 4, Priority::Standard))
        .collect();
    let mut o = opts(4, 16);
    o.keep_outputs = true;

    let sequential = serve_sequential(&requests, &o).unwrap();
    let continuous = serve_continuous(&requests, &o).unwrap();

    // the batcher really batches on this workload...
    assert_eq!(sequential.max_occupancy(), 1);
    assert!(
        continuous.max_occupancy() > 1,
        "continuous path never had >1 request in flight (max {})",
        continuous.max_occupancy()
    );

    // ...and still produces the same decode outputs per request
    for r in &requests {
        let a = &sequential.outputs[&r.id];
        let b = &continuous.outputs[&r.id];
        assert_eq!(a.len(), r.decode_tokens, "sequential output count, req {}", r.id);
        assert_eq!(b.len(), r.decode_tokens, "continuous output count, req {}", r.id);
        for (t, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                x.allclose(y, 1e-4),
                "req {} decode token {}: outputs diverge by {}",
                r.id,
                t,
                x.max_abs_diff(y)
            );
        }
    }

    // conservation: no preemption means every token is served exactly once
    assert_eq!(continuous.preemptions, 0);
    let total_seq: usize = requests.iter().map(|r| r.seq_len).sum();
    let total_dec: usize = requests.iter().map(|r| r.decode_tokens).sum();
    assert_eq!(continuous.total_prefill_tokens, total_seq);
    assert_eq!(continuous.total_decode_tokens, total_dec);
    assert_eq!(sequential.total_prefill_tokens, total_seq);
}

#[test]
fn preemption_respects_kv_budget_and_replays_exactly() {
    // 3 requests of 32 prompt + 8 decode tokens against a 96-token budget:
    // all three prompts reserve exactly 96, so the first decode step's
    // appends must force a preemption.
    let requests: Vec<Request> = (0..3).map(|id| req(id, 32, 8, Priority::Standard)).collect();
    let mut tight = opts(2, 16);
    tight.kv_budget_tokens = 96;
    tight.max_step_tokens = 64;
    tight.keep_outputs = true;

    let report = serve_continuous(&requests, &tight).unwrap();
    assert_eq!(report.requests.len(), 3, "every request must finish");
    assert!(report.preemptions >= 1, "decode growth over the budget must preempt");

    // the budget invariant holds at every step (peak residency after the
    // step's appends)
    common::assert_kv_budget_invariant(&report, "preemption");
    let preempted: usize = report.requests.iter().map(|r| r.preemptions).sum();
    assert_eq!(preempted, report.preemptions);

    // replay determinism: the preempted request's outputs equal the
    // sequential path's under a roomy budget
    let mut roomy = opts(2, 16);
    roomy.keep_outputs = true;
    let oracle = serve_sequential(&requests, &roomy).unwrap();
    assert_eq!(oracle.preemptions, 0);
    for r in &requests {
        let a = &oracle.outputs[&r.id];
        let b = &report.outputs[&r.id];
        assert_eq!(b.len(), r.decode_tokens);
        for (t, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                x.allclose(y, 1e-4),
                "req {} decode token {} diverges after preemption replay ({})",
                r.id,
                t,
                x.max_abs_diff(y)
            );
        }
    }
}

#[test]
fn aging_bounds_fcfs_starvation() {
    // One batch-class request at t=0 behind a stream of 20 interactive
    // requests: with max_batch=1 each request occupies the engine for 3
    // steps (1 prefill + 2 decode), so strict priority would admit the
    // batch request last (step 60). Aging must bound its wait.
    let mut requests = vec![req(0, 16, 2, Priority::Batch)];
    for i in 1..=20 {
        requests.push(req(i, 16, 2, Priority::Interactive));
    }
    let mut o = opts(2, 16);
    o.max_batch = 1;
    o.aging_steps = 4;
    let aged = serve_continuous(&requests, &o).unwrap();
    let batch_req = aged.requests.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(batch_req.eligible_step, 0);
    assert!(
        batch_req.admitted_step <= 8,
        "aging (4 steps) should admit the batch request within two service \
         slots, got step {}",
        batch_req.admitted_step
    );

    // anti-test: with aging effectively disabled the same request starves
    // until every interactive request has finished
    let mut starve = o.clone();
    starve.aging_steps = 1_000_000;
    let starved = serve_continuous(&requests, &starve).unwrap();
    let starved_req = starved.requests.iter().find(|r| r.id == 0).unwrap();
    assert!(
        starved_req.admitted_step > batch_req.admitted_step,
        "without aging the batch request should wait longer ({} vs {})",
        starved_req.admitted_step,
        batch_req.admitted_step
    );
    assert!(
        starved_req.admitted_step >= 30,
        "without aging the batch request should be admitted near the end, \
         got step {}",
        starved_req.admitted_step
    );
}

#[test]
fn poisson_mix_keeps_multiple_requests_in_flight() {
    let mix = ServeMix::preset("poisson", 1e5, 8).unwrap();
    let requests = mix.generate(8, 3);
    let o = opts(2, 32);
    let report = serve_continuous(&requests, &o).unwrap();

    assert_eq!(report.requests.len(), 8);
    assert!(
        report.max_occupancy() > 1,
        "Poisson mix at high rate must overlap requests (max occupancy {})",
        report.max_occupancy()
    );
    assert!(report.mean_occupancy() > 1.0);
    assert!(report.throughput_tokens_per_s() > 0.0);

    let ttft = report.ttft_summary();
    let tpot = report.tpot_summary();
    let qd = report.queue_delay_summary();
    assert_eq!(ttft.n, 8);
    assert_eq!(tpot.n, 8);
    assert!(ttft.p50 > 0.0 && ttft.p95 >= ttft.p50);
    assert!(tpot.p50 > 0.0);
    assert!(qd.min >= 0.0);

    for r in &report.requests {
        assert!(r.first_token >= r.admitted);
        assert!(r.finish >= r.first_token);
        assert!(r.queue_delay() >= 0.0);
    }
    for s in &report.steps {
        assert!(s.kv_tokens <= s.kv_budget);
        assert!(s.batch >= 1 && s.batch <= s.running);
    }
}

#[test]
fn actor_runtime_matches_spawn_per_step_on_every_mix() {
    // The equivalence proof for the persistent runtime: over each
    // registered workload mix, the actor ring and the legacy per-step
    // spawn path serve the same requests to the same decode outputs
    // (merge order may differ between runtimes, hence allclose, not
    // bit equality).
    for &mix_name in ServeMix::NAMES {
        let requests = mix_requests(mix_name, 6, 3);
        let mut o = opts(2, 32);
        o.keep_outputs = true;

        o.runtime = ServeRuntime::SpawnPerStep;
        let legacy = serve_continuous(&requests, &o).unwrap();
        o.runtime = ServeRuntime::Actors;
        let actors = serve_continuous(&requests, &o).unwrap();

        assert_eq!(legacy.requests.len(), requests.len(), "{mix_name}");
        assert_eq!(actors.requests.len(), requests.len(), "{mix_name}");
        assert_eq!(
            actors.total_prefill_tokens, legacy.total_prefill_tokens,
            "{mix_name}: prefill totals"
        );
        assert_eq!(
            actors.total_decode_tokens, legacy.total_decode_tokens,
            "{mix_name}: decode totals"
        );
        for r in &requests {
            let a = &legacy.outputs[&r.id];
            let b = &actors.outputs[&r.id];
            assert_eq!(a.len(), b.len(), "{mix_name} req {}: output count", r.id);
            for (t, (x, y)) in a.iter().zip(b).enumerate() {
                assert!(
                    x.allclose(y, 1e-4),
                    "{mix_name} req {} decode token {t}: runtimes diverge by {}",
                    r.id,
                    x.max_abs_diff(y)
                );
            }
        }
    }
}

#[test]
fn bursty_mix_batches_simultaneous_arrivals() {
    let mix = ServeMix::preset("bursty", 200.0, 8).unwrap();
    let requests = mix.generate(8, 1);
    let o = opts(2, 32);
    let report = serve_continuous(&requests, &o).unwrap();
    assert_eq!(report.requests.len(), 8);
    // a burst of 4 arrives at one instant: they must share steps
    assert!(
        report.max_occupancy() >= 2,
        "burst arrivals must batch (max occupancy {})",
        report.max_occupancy()
    );
}
