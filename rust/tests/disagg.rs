//! Acceptance tests for disaggregated prefill/decode serving (ISSUE 10).
//!
//! The contract: splitting a session's devices into a prefill pool and a
//! decode pool connected by a modeled KV handoff is *numerically
//! invisible*. Concretely:
//!
//! 1. Per-request decode outputs match the unified continuous loop run
//!    over the same P+D devices (1e-4 allclose; merge rounding differs
//!    because the rings have different widths) — over every registered
//!    workload mix, every pool split, and every KV storage dtype.
//! 2. At f32 with chunk-aligned prompts and non-binding caps, the disagg
//!    run is digest-*exact* against the unified loop at `devices = D`
//!    (the decode ring's width): the handed-off KV regenerates bit-equal
//!    rows and page layout depends only on total tokens, not on append
//!    granularity.
//! 3. Handoff conservation: prefill-pool delta tokens == shipped tokens
//!    == decode-pool imported tokens == total prompt tokens.
//! 4. The KV-budget invariant holds at every step of *both* pool traces,
//!    including under decode-pool preemption pressure.

mod common;

use std::collections::HashMap;

use common::{mix_requests, req, serve_opts, std_requests};
use tokenring::scheduler::{
    serve_continuous, serve_continuous_warm, serve_disagg, serve_disagg_warm,
    ContinuousServeOpts, ContinuousServeReport, DisaggOpts, DisaggReport, PoolSplit,
    TokenSource, WarmStart,
};
use tokenring::tensor::Dtype;
use tokenring::workload::{Priority, Request, ServeMix, SharedPrefix};

/// The pool splits under test: the narrowest possible, asymmetric, and
/// symmetric-wide (2..4 devices).
const SPLITS: [&str; 3] = ["1p+1d", "2p+1d", "2p+2d"];

fn split(s: &str) -> PoolSplit {
    PoolSplit::parse(s).unwrap().unwrap()
}

fn opts_for(devices: usize, dt: Dtype) -> ContinuousServeOpts {
    let mut o = serve_opts(devices, 16);
    o.keep_outputs = true;
    o.engine.kv_dtype = dt;
    o
}

fn run_unified(requests: &[Request], devices: usize, dt: Dtype) -> ContinuousServeReport {
    serve_continuous(requests, &opts_for(devices, dt)).unwrap()
}

fn run_disagg(requests: &[Request], split_name: &str, dt: Dtype) -> DisaggReport {
    let sp = split(split_name);
    let o = opts_for(sp.devices(), dt);
    serve_disagg(requests, &o, &DisaggOpts::new(sp)).unwrap()
}

fn assert_pool_invariants(report: &DisaggReport, label: &str) {
    for (pool_name, pool) in [("prefill", &report.prefill), ("decode", &report.decode)] {
        for s in &pool.steps {
            assert!(
                s.kv_tokens <= s.kv_budget,
                "{label} {pool_name} step {}: resident {} tokens over budget {}",
                s.step,
                s.kv_tokens,
                s.kv_budget
            );
        }
    }
}

fn assert_handoff_conservation(report: &DisaggReport, requests: &[Request], label: &str) {
    let prompt_tokens: usize = requests.iter().map(|r| r.seq_len).sum();
    let h = &report.handoff;
    assert_eq!(h.requests, requests.len(), "{label}: every request hands off once");
    assert_eq!(h.tokens, prompt_tokens, "{label}: shipped tokens == prompt tokens");
    assert_eq!(h.imported_tokens, prompt_tokens, "{label}: imported == shipped");
    assert_eq!(h.latencies.len(), h.requests, "{label}: one latency sample per handoff");
    assert!(h.latencies.iter().all(|&l| l > 0.0), "{label}: transfers take time");
    assert!(h.bytes > 0, "{label}: the cost model must charge bytes");
}

#[test]
fn disagg_matches_unified_on_every_mix_split_and_dtype() {
    // The full equivalence grid. The unified oracle runs over the same
    // P+D devices with the same KV storage dtype; per-request decode
    // outputs must agree to 1e-4 and digests to 1e-3 — batching across
    // two pools instead of one is invisible.
    for &mix_name in ServeMix::NAMES {
        let requests = mix_requests(mix_name, 5, 3);
        for dt in [Dtype::F32, Dtype::Bf16, Dtype::F16] {
            let mut oracle: HashMap<usize, ContinuousServeReport> = HashMap::new();
            for split_name in SPLITS {
                let label = format!("{mix_name}/{split_name}/{}", dt.name());
                let devices = split(split_name).devices();
                let unified = oracle
                    .entry(devices)
                    .or_insert_with(|| run_unified(&requests, devices, dt));
                let disagg = run_disagg(&requests, split_name, dt);

                assert_eq!(disagg.core.requests.len(), requests.len(), "{label}");
                assert_eq!(
                    disagg.core.total_prefill_tokens, unified.total_prefill_tokens,
                    "{label}: prefill totals"
                );
                assert_eq!(
                    disagg.core.total_decode_tokens, unified.total_decode_tokens,
                    "{label}: decode totals"
                );
                common::assert_outputs_close(
                    &common::outputs_map(&disagg.core),
                    &common::outputs_map(unified),
                    1e-4,
                    &label,
                );
                common::assert_digests_match(
                    &common::digests(&disagg.core),
                    &common::digests(unified),
                    1e-3,
                    &label,
                );
                assert_handoff_conservation(&disagg, &requests, &label);
                assert_pool_invariants(&disagg, &label);
            }
        }
    }
}

#[test]
fn disagg_is_digest_exact_against_unified_at_decode_width_f32() {
    // The bit-for-bit oracle leg: with chunk-aligned prompts, roomy caps
    // and f32 storage, every split with a <=2-wide decode ring must land
    // digest-*equal* (not allclose) on the unified loop at devices = D.
    // (Wider decode rings merge remote partials in arrival order, so
    // exactness stops at D = 2.)
    let requests: Vec<Request> = (0..6)
        .map(|id| req(id, 32 + 16 * (id % 3), 4, Priority::Standard))
        .collect();
    for split_name in ["1p+1d", "2p+1d", "3p+1d", "2p+2d", "3p+2d"] {
        let sp = split(split_name);
        let disagg = run_disagg(&requests, split_name, Dtype::F32);
        let unified = run_unified(&requests, sp.decode, Dtype::F32);
        assert_eq!(disagg.core.preemptions, 0, "{split_name}: caps must not bind");
        assert_eq!(unified.preemptions, 0, "{split_name}: oracle caps must not bind");
        let got = common::digests(&disagg.core);
        let want = common::digests(&unified);
        assert_eq!(
            got, want,
            "{split_name}: disagg digests must be bit-equal to unified at devices={}",
            sp.decode
        );
    }
}

#[test]
fn handoff_bytes_follow_the_kv_dtype() {
    // The transfer cost model charges real KvDelta bytes: K+V rows at the
    // storage dtype plus a 4-byte position index per token. Packing to
    // bf16/f16 must halve the row payload, not the position index.
    let requests = std_requests(4);
    let o = opts_for(2, Dtype::F32);
    let row = |dt: Dtype| 2 * o.heads * o.head_dim * dt.bytes_per_el() + 4;
    for dt in [Dtype::F32, Dtype::Bf16, Dtype::F16] {
        let report = run_disagg(&requests, "1p+1d", dt);
        let prompt_tokens: usize = requests.iter().map(|r| r.seq_len).sum();
        assert_eq!(
            report.handoff.bytes,
            prompt_tokens * row(dt),
            "dtype={}: handoff bytes",
            dt.name()
        );
    }
}

#[test]
fn decode_pool_preemption_respects_budget_and_replays_exactly() {
    // A budget that fits the three 32-token prompts exactly: the first
    // decode appends must preempt in the decode pool. The invariant holds
    // at every step of both pool traces and the preempted requests replay
    // to the roomy run's digests.
    let requests: Vec<Request> = (0..3).map(|id| req(id, 32, 8, Priority::Standard)).collect();
    let sp = split("1p+1d");
    let mut tight = opts_for(2, Dtype::F32);
    tight.kv_budget_tokens = 96;
    tight.max_step_tokens = 64;
    let report = serve_disagg(&requests, &tight, &DisaggOpts::new(sp)).unwrap();

    assert_eq!(report.core.requests.len(), 3, "every request must finish");
    assert!(report.core.preemptions >= 1, "decode growth over the budget must preempt");
    assert_pool_invariants(&report, "tight");
    // re-imports after preemption repeat the shipment, never lose it
    assert!(report.handoff.imported_tokens >= report.handoff.tokens);

    let roomy = run_disagg(&requests, "1p+1d", Dtype::F32);
    assert_eq!(roomy.core.preemptions, 0);
    common::assert_digests_match(
        &common::digests(&report.core),
        &common::digests(&roomy.core),
        1e-9,
        "preemption replay",
    );
}

#[test]
fn warm_started_prefill_elides_the_prefix_and_matches_cold() {
    // The fleet's prefix cache hands disagg replicas a WarmStart exactly
    // as it does unified ones: the prefix KV is imported at prefill-pool
    // admission, the accounting moves from prefilled to elided, and the
    // decode outputs do not move.
    let prefix = SharedPrefix { group: 3, tokens: 32 };
    let requests: Vec<Request> = (0..2)
        .map(|id| Request {
            id,
            seq_len: 64,
            arrival: 0.0,
            decode_tokens: 4,
            priority: Priority::Standard,
            prefix: Some(prefix),
        })
        .collect();
    let o = opts_for(2, Dtype::F32);
    let d = DisaggOpts::new(split("1p+1d"));

    let cold = serve_disagg(&requests, &o, &d).unwrap();

    let source = TokenSource::new(o.seed, o.heads, o.head_dim);
    let (k, v) = source.prefix_kv(prefix.group, prefix.tokens);
    let mut warm = HashMap::new();
    warm.insert(1usize, WarmStart::new(k, v).unwrap());
    let warmed = serve_disagg_warm(&requests, &o, &d, &warm).unwrap();

    assert_eq!(warmed.core.prefill_tokens_elided, prefix.tokens);
    assert_eq!(
        warmed.core.total_prefill_tokens + prefix.tokens,
        cold.core.total_prefill_tokens,
        "every prompt token is either prefilled or elided"
    );
    // the handoff still ships the *full* prompt (the decode pool needs
    // the prefix rows too, wherever they came from)
    assert_eq!(warmed.handoff.tokens, 2 * 64);
    common::assert_outputs_close(
        &common::outputs_map(&warmed.core),
        &common::outputs_map(&cold.core),
        1e-4,
        "warm-vs-cold",
    );
}

#[test]
fn zero_decode_requests_complete_at_import() {
    // A prefill-only request (decode_tokens = 0) finishes the moment its
    // KV lands in the decode pool: TTFT == finish, no decode steps burn.
    let requests = vec![req(0, 32, 0, Priority::Standard), req(1, 32, 2, Priority::Standard)];
    let report = run_disagg(&requests, "1p+1d", Dtype::F32);
    assert_eq!(report.core.requests.len(), 2);
    let r0 = report.core.requests.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(r0.decode_tokens, 0);
    assert_eq!(r0.first_token, r0.finish, "zero-decode retires at import");
    assert_handoff_conservation(&report, &requests, "zero-decode");
}

#[test]
fn ttft_includes_the_modeled_handoff_latency() {
    // On a slow uniform link the transfer time dominates: every
    // first-token latency must be at least its request's handoff latency
    // (the decode pool cannot answer before the KV arrives).
    let requests = std_requests(3);
    let sp = split("1p+1d");
    let o = opts_for(2, Dtype::F32);
    let mut d = DisaggOpts::new(sp);
    d.cluster = "uniform:1".to_string();
    let report = serve_disagg(&requests, &o, &d).unwrap();
    let min_latency = report
        .handoff
        .latencies
        .iter()
        .cloned()
        .fold(f64::INFINITY, f64::min);
    for r in &report.core.requests {
        assert!(
            r.ttft() >= min_latency,
            "req {}: ttft {} beats the fastest possible handoff {}",
            r.id,
            r.ttft(),
            min_latency
        );
    }
}
