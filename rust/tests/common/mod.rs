//! Shared helpers for the integration-test suite. Each test binary
//! compiles this module independently (`mod common;`), so helpers unused
//! by a given binary are expected — hence the blanket `dead_code` allow.
//!
//! Everything here is deduplicated from serve_scheduler.rs / chaos.rs /
//! fleet.rs / actor_ring.rs / kernel_equivalence.rs: serve-opts and
//! request builders, workload-mix generation, digest and output diffing,
//! and the randomized shape generator for the kernel property sweep.

#![allow(dead_code)]

use std::collections::HashMap;

use tokenring::engine::decode::DecodeQuery;
use tokenring::scheduler::{ContinuousServeOpts, ContinuousServeReport};
use tokenring::tensor::Tensor;
use tokenring::util::rng::Rng;
use tokenring::workload::{Priority, Request, ServeMix};

/// Head count shared by the ring-level tests (actor_ring, disagg).
pub const HEADS: usize = 2;
/// Head dim shared by the ring-level tests.
pub const HEAD_DIM: usize = 8;

/// The canonical small serve configuration (2-head / 8-dim requests,
/// roomy budgets, seed 42). serve_scheduler and chaos use it as-is;
/// fleet and disagg tweak fields on top.
pub fn serve_opts(devices: usize, chunk: usize) -> ContinuousServeOpts {
    ContinuousServeOpts {
        devices,
        heads: HEADS,
        head_dim: HEAD_DIM,
        chunk,
        max_batch: 8,
        max_step_tokens: 512,
        kv_budget_tokens: 1 << 20,
        aging_steps: 16,
        seed: 42,
        keep_outputs: false,
        ..Default::default()
    }
}

/// An all-at-t=0 request with an explicit priority class.
pub fn req(id: usize, seq_len: usize, decode: usize, priority: Priority) -> Request {
    Request { id, seq_len, arrival: 0.0, decode_tokens: decode, priority, prefix: None }
}

/// The standard n-request workload the chaos and equivalence tests share:
/// staggered 32/48/64-token prompts, 4 decode tokens each, all standard
/// priority at t=0.
pub fn std_requests(n: usize) -> Vec<Request> {
    (0..n).map(|id| req(id, 32 + 16 * (id % 3), 4, Priority::Standard)).collect()
}

/// Generate `n` requests from a registered [`ServeMix`] preset at a high
/// arrival rate (so requests overlap) with 32-token length granularity.
pub fn mix_requests(mix_name: &str, n: usize, seed: u64) -> Vec<Request> {
    ServeMix::preset(mix_name, 1e5, 32)
        .unwrap_or_else(|e| panic!("mix '{mix_name}': {e:#}"))
        .generate(n, seed)
}

/// Per-request output digests in id order (reports sort by id).
pub fn digests(report: &ContinuousServeReport) -> Vec<f64> {
    report.requests.iter().map(|r| r.output_digest).collect()
}

/// Absolute-tolerance digest comparison against a reference run.
pub fn assert_digests_match(got: &[f64], want: &[f64], tol: f64, label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: request count");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            (a - b).abs() < tol,
            "{label}: request {i} digest diverges from the reference run ({a} vs {b})"
        );
    }
}

/// Clone a report's id-keyed decode outputs (requires `keep_outputs`).
pub fn outputs_map(report: &ContinuousServeReport) -> HashMap<usize, Vec<Tensor>> {
    report.outputs.iter().map(|(id, toks)| (*id, toks.clone())).collect()
}

/// Element-wise allclose over two id-keyed output maps: same request
/// set, same token counts, every decode token within `tol`.
pub fn assert_outputs_close(
    a: &HashMap<usize, Vec<Tensor>>,
    b: &HashMap<usize, Vec<Tensor>>,
    tol: f32,
    label: &str,
) {
    assert_eq!(a.len(), b.len(), "{label}: request counts");
    for (id, xs) in a {
        let ys = b.get(id).unwrap_or_else(|| panic!("{label}: request {id} missing"));
        assert_eq!(xs.len(), ys.len(), "{label} req {id}: output count");
        for (t, (x, y)) in xs.iter().zip(ys).enumerate() {
            assert!(
                x.allclose(y, tol),
                "{label} req {id} decode token {t}: diverges by {}",
                x.max_abs_diff(y)
            );
        }
    }
}

/// Every step's resident-KV budget invariant, over a report's trace.
pub fn assert_kv_budget_invariant(report: &ContinuousServeReport, label: &str) {
    for s in &report.steps {
        assert!(
            s.kv_tokens <= s.kv_budget,
            "{label} step {}: resident {} tokens over budget {}",
            s.step,
            s.kv_tokens,
            s.kv_budget
        );
    }
}

/// A normally-distributed tensor for kernel/ring tests.
pub fn rand_t(rng: &mut Rng, shape: &[usize]) -> Tensor {
    Tensor::new(shape, rng.normal_vec(shape.iter().product(), 1.0))
}

/// A single-token decode query at `pos` using the shared HEADS/HEAD_DIM.
pub fn decode_query(rng: &mut Rng, req: usize, pos: i32) -> DecodeQuery {
    DecodeQuery {
        request: req,
        q: Tensor::new(&[1, HEADS, HEAD_DIM], rng.normal_vec(HEADS * HEAD_DIM, 1.0)),
        q_pos: vec![pos],
    }
}

/// One randomized attention-shape case for the kernel property sweep.
#[derive(Debug, Clone, Copy)]
pub struct PropShape {
    pub sq: usize,
    pub skv: usize,
    pub h: usize,
    pub h_kv: usize,
    pub d: usize,
    pub causal: bool,
    /// Query position offset: places the causal frontier inside, before,
    /// and after the key range across trials.
    pub q_offset: i32,
}

impl PropShape {
    pub fn q_positions(&self) -> Vec<i32> {
        (self.q_offset..self.q_offset + self.sq as i32).collect()
    }

    pub fn k_positions(&self) -> Vec<i32> {
        (0..self.skv as i32).collect()
    }

    pub fn label(&self, trial: usize) -> String {
        format!(
            "trial={trial} sq={} skv={} h={}/{} d={} causal={}",
            self.sq, self.skv, self.h, self.h_kv, self.d, self.causal
        )
    }
}

/// Deterministic randomized shape generator: `trials` cases straddling
/// Q_TILE/KV_TILE boundaries with mixed GQA group layouts. Seed 7002 with
/// 40 trials reproduces the historical kernel_equivalence sweep exactly.
pub fn prop_shapes(seed: u64, trials: usize) -> Vec<PropShape> {
    let mut shape_rng = Rng::new(seed);
    (0..trials)
        .map(|trial| {
            let sq = 1 + (shape_rng.normal_vec(1, 1.0)[0].abs() * 37.0) as usize % 97;
            let skv = 1 + (shape_rng.normal_vec(1, 1.0)[0].abs() * 53.0) as usize % 180;
            let d = [4usize, 8, 16][trial % 3];
            let (h, h_kv) = [(1usize, 1usize), (2, 1), (4, 2), (4, 4)][trial % 4];
            let causal = trial % 2 == 0;
            let q_offset = (trial % 5) as i32 * (skv as i32 / 2).max(1) / 2;
            PropShape { sq, skv, h, h_kv, d, causal, q_offset }
        })
        .collect()
}
