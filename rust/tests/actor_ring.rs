//! Lifecycle tests for the persistent actor-ring runtime
//! (`engine::actors`): admit/evict/re-admit replay, clean shutdown with
//! no leaked threads, and the delta-token conservation property between
//! the ring and the paged KV cache.

mod common;

use std::collections::HashMap;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use common::{decode_query as query, rand_t, HEADS, HEAD_DIM};
use tokenring::attention::attention_block;
use tokenring::engine::actors::{ActorRing, RingPolicy};
use tokenring::engine::faults::{FaultInjector, FaultPlan};
use tokenring::engine::kv_cache::{KvCache, KvDelta};
use tokenring::engine::EngineOpts;
use tokenring::tensor::Tensor;
use tokenring::util::rng::Rng;

fn opts() -> EngineOpts {
    EngineOpts { record: false, ..Default::default() }
}

/// Fill a cache with `(request, context_tokens)` pairs; returns the cache
/// plus each request's full (k, v) for oracle checks.
fn filled_cache(
    n: usize,
    reqs: &[(usize, usize)],
    rng: &mut Rng,
) -> (KvCache, HashMap<usize, (Tensor, Tensor)>) {
    let mut cache = KvCache::new(n, HEADS, HEAD_DIM, 8);
    let mut truth = HashMap::new();
    for &(req, ctx) in reqs {
        let k = rand_t(rng, &[ctx, HEADS, HEAD_DIM]);
        let v = rand_t(rng, &[ctx, HEADS, HEAD_DIM]);
        cache.append(req, &k, &v).unwrap();
        truth.insert(req, (k, v));
    }
    (cache, truth)
}

/// Admit `req` and ship every non-empty device view as one delta — the
/// replay path a preempted-then-readmitted request takes.
fn admit_and_load(ring: &mut ActorRing, cache: &KvCache, req: usize) {
    ring.admit(req).unwrap();
    for dev in 0..ring.devices() {
        let (k, v, positions) = cache.device_view(req, dev).unwrap();
        if !positions.is_empty() {
            ring.append(&[KvDelta::new(req, dev, k, v, positions, 0)]).unwrap();
        }
    }
}

#[test]
fn evict_and_readmit_replays_identical_outputs() {
    // On a 2-device ring the merge order is fixed (own partial first, one
    // remote after), so a replay from the same cache state must be
    // bit-identical, not just allclose.
    let mut rng = Rng::new(71);
    let (cache, _) = filled_cache(2, &[(1, 48)], &mut rng);
    let mut ring = ActorRing::spawn(2, HEADS, HEAD_DIM, &opts()).unwrap();

    admit_and_load(&mut ring, &cache, 1);
    let dq = query(&mut rng, 1, 48);
    let before = ring.step(vec![dq.clone()]).unwrap();

    ring.evict(1).unwrap();
    assert!(!ring.is_resident(1));
    admit_and_load(&mut ring, &cache, 1); // replay from the cache
    let after = ring.step(vec![dq]).unwrap();

    let (o0, l0) = &before.outputs[&1];
    let (o1, l1) = &after.outputs[&1];
    assert_eq!(o0.max_abs_diff(o1), 0.0, "n=2 replay must be exact");
    assert_eq!(l0.max_abs_diff(l1), 0.0);
    ring.shutdown().unwrap();
}

#[test]
fn readmit_on_wide_ring_matches_oracle() {
    // n=4: remote partials can merge in any arrival order, so the replay
    // contract is allclose against the single-device oracle, before and
    // after the evict/re-admit cycle.
    let mut rng = Rng::new(72);
    let (cache, truth) = filled_cache(4, &[(2, 64)], &mut rng);
    let mut ring = ActorRing::spawn(4, HEADS, HEAD_DIM, &opts()).unwrap();
    let (k, v) = &truth[&2];
    let kpos: Vec<i32> = (0..64).collect();

    for round in 0..2 {
        admit_and_load(&mut ring, &cache, 2);
        let dq = query(&mut rng, 2, 64);
        let res = ring.step(vec![dq.clone()]).unwrap();
        let (eo, _) = attention_block(&dq.q, k, v, &dq.q_pos, &kpos, true, None);
        let (got, _) = &res.outputs[&2];
        assert!(
            got.allclose(&eo, 1e-4),
            "round {round} diff={}",
            got.max_abs_diff(&eo)
        );
        ring.evict(2).unwrap();
    }
    ring.shutdown().unwrap();
}

#[test]
fn single_page_request_leaves_most_devices_empty_yet_matches_oracle() {
    // 8 tokens = one page on a 4-device ring: three actors hold an empty
    // view and must still emit masked partials so the merge count closes.
    let mut rng = Rng::new(73);
    let (cache, truth) = filled_cache(4, &[(0, 8)], &mut rng);
    let mut ring = ActorRing::spawn(4, HEADS, HEAD_DIM, &opts()).unwrap();
    admit_and_load(&mut ring, &cache, 0);
    let dq = query(&mut rng, 0, 8);
    let res = ring.step(vec![dq.clone()]).unwrap();
    let (k, v) = &truth[&0];
    let kpos: Vec<i32> = (0..8).collect();
    let (eo, _) = attention_block(&dq.q, k, v, &dq.q_pos, &kpos, true, None);
    let (got, _) = &res.outputs[&0];
    assert!(got.allclose(&eo, 1e-4), "diff={}", got.max_abs_diff(&eo));
    ring.shutdown().unwrap();
}

#[test]
fn shutdown_drains_cleanly_with_no_leaked_threads() {
    // Run a full session (admit → steps → drain → shutdown) on a helper
    // thread; if any actor thread leaks or a join hangs, the helper never
    // reports back and the timeout fails the test instead of wedging CI.
    let (done_tx, done_rx) = channel();
    let helper = std::thread::spawn(move || {
        let mut rng = Rng::new(74);
        let (cache, _) = filled_cache(3, &[(0, 24), (1, 24)], &mut rng);
        let mut ring = ActorRing::spawn(3, HEADS, HEAD_DIM, &opts()).unwrap();
        admit_and_load(&mut ring, &cache, 0);
        admit_and_load(&mut ring, &cache, 1);
        for step in 0..4 {
            let qs = vec![query(&mut rng, 0, 24 + step), query(&mut rng, 1, 24 + step)];
            let res = ring.step(qs).unwrap();
            assert_eq!(res.outputs.len(), 2);
        }
        let report = ring.drain().unwrap();
        assert_eq!(report.delta_tokens(), 48, "two 24-token loads");
        assert_eq!(report.stats.len(), 3);
        // shutdown() joins every worker; an Err here means a panic leaked
        ring.shutdown().unwrap();
        done_tx.send(()).unwrap();
    });
    done_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("session did not drain+shutdown within 30s (leaked or hung actor thread)");
    helper.join().unwrap();
}

#[test]
fn drop_without_explicit_shutdown_joins_workers() {
    let (done_tx, done_rx) = channel();
    let helper = std::thread::spawn(move || {
        let mut ring = ActorRing::spawn(4, HEADS, HEAD_DIM, &opts()).unwrap();
        ring.admit(11).unwrap();
        drop(ring); // Drop must send Shutdown and join all four workers
        done_tx.send(()).unwrap();
    });
    done_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("dropping the ring did not join its workers within 30s");
    helper.join().unwrap();
}

#[test]
fn dropping_a_poisoned_ring_under_a_stalled_reply_is_bounded() {
    // Satellite regression for ActorRing::Drop: a worker wedged in an
    // injected 5 s stall must be detached after a bounded grace, not
    // joined for the whole stall (let alone forever).
    let (done_tx, done_rx) = channel();
    let helper = std::thread::spawn(move || {
        let mut rng = Rng::new(76);
        let (cache, _) = filled_cache(2, &[(1, 48)], &mut rng);
        let inj = Arc::new(FaultInjector::new(&FaultPlan::parse("stall@0:1:5000").unwrap()));
        let policy = RingPolicy { watchdog: Duration::from_millis(10), max_retries: 1 };
        let mut ring =
            ActorRing::spawn_with(2, HEADS, HEAD_DIM, &opts(), policy, Some(inj)).unwrap();
        admit_and_load(&mut ring, &cache, 1);
        let dq = query(&mut rng, 1, 48);
        let err = ring.step(vec![dq]).unwrap_err().to_string();
        assert!(err.contains("stalled"), "{err}");
        assert!(ring.is_poisoned());
        let t0 = std::time::Instant::now();
        drop(ring);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "dropping the poisoned ring took {:?} (must detach, not wait out the stall)",
            t0.elapsed()
        );
        done_tx.send(()).unwrap();
    });
    done_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("dropping a poisoned ring under a stalled reply wedged the session");
    helper.join().unwrap();
}

#[test]
fn delta_tokens_shipped_equals_kv_cache_growth() {
    // Conservation property: route every `KvCache::append_deltas` result
    // through the ring and the actors' drained delta-token total must
    // equal the cache's token growth — nothing lost, nothing duplicated,
    // nothing shipped twice.
    let mut rng = Rng::new(75);
    let n = 3;
    let mut cache = KvCache::new(n, HEADS, HEAD_DIM, 4);
    let mut ring = ActorRing::spawn(n, HEADS, HEAD_DIM, &opts()).unwrap();
    let base = cache.total_tokens();

    for req in 0..5 {
        ring.admit(req).unwrap();
    }
    // 40 random-length appends across 5 requests, page size 4 so most
    // appends split into several per-device deltas
    for i in 0..40 {
        let req = (i * 7 + 3) % 5;
        let t = 1 + (i * 5 + 1) % 9;
        let sz = t * HEADS * HEAD_DIM;
        let k = Tensor::new(&[t, HEADS, HEAD_DIM], rng.normal_vec(sz, 1.0));
        let v = Tensor::new(&[t, HEADS, HEAD_DIM], rng.normal_vec(sz, 1.0));
        let deltas = cache.append_deltas(req, &k, &v).unwrap();
        assert_eq!(deltas.iter().map(KvDelta::tokens).sum::<usize>(), t);
        ring.append(&deltas).unwrap();
    }

    let grown = cache.total_tokens() - base;
    assert_eq!(ring.delta_tokens_sent(), grown, "driver-side counter");
    let report = ring.drain().unwrap();
    assert_eq!(report.delta_tokens(), grown, "actor-side conservation");
    assert!(report.delta_bytes() > 0);
    ring.shutdown().unwrap();
}
