//! Scheduler equivalence: the event-driven heap scheduler behind
//! `simulate()` must reproduce the reference greedy ready-set scan
//! (`simulate_reference`) EXACTLY — same span per task, same makespan, to
//! 1e-9 — on:
//!
//! * random task graphs (random DAG shapes, contended resources, duplicate
//!   durations to force `(start, id)` tie-breaks), and
//! * every `Schedule` the repo ships × the §2.2 topology presets
//!   (pcie_a10_default, oam_mesh, nvswitch) × causal/partition variants.
//!
//! This is what licenses every figure/table to run on the O(n log n) path.

use tokenring::comm::{AttnShape, ComputeModel, Dtype};
use tokenring::parallelism::hybrid::HybridTokenRing;
use tokenring::parallelism::partition::Partition;
use tokenring::parallelism::ring_attention::RingAttention;
use tokenring::parallelism::tensor_parallel::TensorParallel;
use tokenring::parallelism::token_ring::TokenRing;
use tokenring::parallelism::ulysses::Ulysses;
use tokenring::parallelism::{AttnJob, Schedule};
use tokenring::simulator::{
    simulate, simulate_reference, ResourceId, SimTask, SpanTag, TaskGraph, TaskLabel,
};
use tokenring::topology::Topology;
use tokenring::util::rng::Rng;

const TOL: f64 = 1e-9;

fn assert_equivalent(g: &TaskGraph, what: &str) {
    let fast = simulate(g);
    let slow = simulate_reference(g);
    assert_eq!(fast.spans.len(), slow.spans.len(), "{what}: span count");
    assert!(
        (fast.makespan - slow.makespan).abs() <= TOL,
        "{what}: makespan {} vs reference {}",
        fast.makespan,
        slow.makespan
    );
    for (a, b) in fast.spans.iter().zip(&slow.spans) {
        assert_eq!(a.task, b.task, "{what}: span order");
        assert!(
            (a.start - b.start).abs() <= TOL && (a.end - b.end).abs() <= TOL,
            "{what}: task {} span ({}, {}) vs reference ({}, {})",
            a.task,
            a.start,
            a.end,
            b.start,
            b.end
        );
    }
}

/// Random DAG with contended resources. Durations are drawn from a small
/// discrete set so identical feasible starts (ties) actually occur and the
/// `(start, task-id)` tie-break is exercised, not just the common path.
fn random_graph(rng: &mut Rng) -> TaskGraph {
    let n_tasks = rng.range(1, 120);
    let n_devices = rng.range(1, 6);
    let mut g = TaskGraph::new();
    for t in 0..n_tasks {
        let dev = rng.below(n_devices);
        // 0..3 deps on earlier tasks (keeps it a DAG by construction)
        let mut deps = Vec::new();
        if t > 0 {
            for _ in 0..rng.below(4) {
                deps.push(rng.below(t));
            }
            deps.sort_unstable();
            deps.dedup();
        }
        // resource set: always the device engine, sometimes a link and/or
        // shared ports, so multi-resource contention is covered
        let mut resources = vec![ResourceId::Compute(dev)];
        if rng.uniform() < 0.4 && n_devices > 1 {
            let dst = (dev + 1 + rng.below(n_devices - 1)) % n_devices;
            resources.push(ResourceId::Link { src: dev, dst });
            if rng.uniform() < 0.5 {
                resources.push(ResourceId::Egress(dev));
                resources.push(ResourceId::Ingress(dst));
            }
        }
        let duration = *rng.choose(&[0.0, 0.25, 0.25, 0.5, 1.0, 1.5]);
        g.add(SimTask {
            label: TaskLabel::Static("rand"),
            device: dev,
            step: t / 8,
            tag: if resources.len() > 1 { SpanTag::SendQ } else { SpanTag::Compute },
            duration,
            resources,
            deps,
        });
    }
    g
}

#[test]
fn random_graphs_match_reference() {
    let mut rng = Rng::new(0xE0E0);
    for trial in 0..200 {
        let g = random_graph(&mut rng);
        assert_equivalent(&g, &format!("random graph trial {trial}"));
    }
}

fn topologies(n: usize) -> Vec<Topology> {
    let mut topos = vec![
        Topology::oam_mesh(n.max(2), 300.0),
        Topology::nvswitch(n.max(2), 150.0),
    ];
    if n == 4 {
        topos.push(Topology::pcie_a10_default());
    }
    topos
}

#[test]
fn all_schedules_on_all_topologies_match_reference() {
    for n in [2usize, 4, 8] {
        for topo in topologies(n) {
            for causal in [false, true] {
                let partition = if causal { Partition::Zigzag } else { Partition::Contiguous };
                let job = AttnJob {
                    shape: AttnShape::new(1024 * topo.num_devices, 16, 64, Dtype::F16),
                    compute: ComputeModel::a10(0.6),
                    causal,
                    partition,
                };
                let schedules: Vec<(&str, Box<dyn Schedule>)> = vec![
                    ("token_ring", Box::new(TokenRing { elide_q: true })),
                    ("token_ring_noelide", Box::new(TokenRing { elide_q: false })),
                    ("ring_attention", Box::new(RingAttention)),
                    ("ulysses", Box::new(Ulysses)),
                    ("tensor_parallel", Box::new(TensorParallel)),
                    ("hybrid_token_ring", Box::new(HybridTokenRing::default())),
                ];
                for (name, sched) in schedules {
                    let g = sched.build(&topo, &job);
                    assert_equivalent(
                        &g,
                        &format!("{name} on {} (causal={causal})", topo.name),
                    );
                }
            }
        }
    }
}

#[test]
fn random_attention_jobs_match_reference() {
    // Randomized job parameters over the ring schedules — duration ties
    // arise naturally here from symmetric blocks.
    let mut rng = Rng::new(0xD1CE);
    for _ in 0..20 {
        let n = *rng.choose(&[2usize, 4, 8]);
        let blk = *rng.choose(&[256usize, 512, 1024]);
        let job = AttnJob {
            shape: AttnShape::new(blk * n, 16, 64, Dtype::F16),
            compute: ComputeModel {
                peak_flops: rng.uniform_range(1e13, 2e14),
                efficiency: rng.uniform_range(0.3, 0.9),
                launch_overhead: 10e-6,
            },
            causal: rng.uniform() < 0.5,
            partition: *rng.choose(&[Partition::Contiguous, Partition::Zigzag]),
        };
        let topo = match rng.below(3) {
            0 => Topology::oam_mesh(n, rng.uniform_range(50.0, 600.0)),
            1 => Topology::nvswitch(n, rng.uniform_range(20.0, 300.0)),
            _ => Topology::uniform_mesh(n, rng.uniform_range(5.0, 100.0)),
        };
        for sched in [&TokenRing::default() as &dyn Schedule, &RingAttention] {
            let g = sched.build(&topo, &job);
            assert_equivalent(&g, &format!("{} on {}", sched.name(), topo.name));
        }
    }
}

#[test]
fn hybrid_on_two_level_matches_reference() {
    for (nodes, per_node) in [(2usize, 2usize), (2, 4), (4, 2)] {
        let topo = Topology::two_level(nodes, per_node, 300.0, 25.0);
        let job = AttnJob {
            shape: AttnShape::new(1024 * nodes * per_node, 16, 64, Dtype::F16),
            compute: ComputeModel::a10(0.6),
            causal: false,
            partition: Partition::Contiguous,
        };
        let g = HybridTokenRing::default().build(&topo, &job);
        assert_equivalent(&g, &format!("hybrid {nodes}x{per_node}"));
    }
}
