//! Integration: AOT artifacts (jax/pallas → HLO text) executed via PJRT in
//! Rust must reproduce the Python oracle's numbers (testdata emitted by
//! `python -m compile.testdata`).

use tokenring::runtime::{default_artifact_dir, ArgValue, Runtime};
use tokenring::tensor::Tensor;
use tokenring::util::json::Json;

fn load_case(name: &str) -> Option<Json> {
    let p = default_artifact_dir().join("testdata").join(name);
    let text = std::fs::read_to_string(&p).ok()?;
    Some(Json::parse(&text).expect("testdata parses"))
}

fn tens(j: &Json, key: &str, shape: &[usize]) -> Tensor {
    Tensor::new(shape, j.get(key).as_f32_vec().expect(key))
}

#[test]
fn attn_causal_tiny_matches_python_oracle() {
    let Some(c) = load_case("attn_causal_tiny.json") else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let (sq, skv) = (c.get("sq").as_usize().unwrap(), c.get("skv").as_usize().unwrap());
    let (h, d) = (c.get("heads").as_usize().unwrap(), c.get("head_dim").as_usize().unwrap());
    let q = tens(&c, "q", &[sq, h, d]);
    let k = tens(&c, "k", &[skv, h, d]);
    let v = tens(&c, "v", &[skv, h, d]);
    let q_pos = c.get("q_pos").as_i32_vec().unwrap();
    let k_pos = c.get("k_pos").as_i32_vec().unwrap();

    let mut rt = Runtime::new(default_artifact_dir()).unwrap();
    let (out, lse) = rt.attn_block("attn_causal_tiny", &q, &k, &v, &q_pos, &k_pos).unwrap();

    let eo = tens(&c, "expect_out", &[sq, h, d]);
    let el = tens(&c, "expect_lse", &[h, sq]);
    assert!(out.allclose(&eo, 1e-4), "out diff={}", out.max_abs_diff(&eo));
    assert!(lse.allclose(&el, 1e-4), "lse diff={}", lse.max_abs_diff(&el));
}

#[test]
fn attn_full_tiny_matches_python_oracle() {
    let Some(c) = load_case("attn_full_tiny.json") else {
        return;
    };
    let (sq, skv) = (c.get("sq").as_usize().unwrap(), c.get("skv").as_usize().unwrap());
    let (h, d) = (c.get("heads").as_usize().unwrap(), c.get("head_dim").as_usize().unwrap());
    let q = tens(&c, "q", &[sq, h, d]);
    let k = tens(&c, "k", &[skv, h, d]);
    let v = tens(&c, "v", &[skv, h, d]);
    let q_pos = c.get("q_pos").as_i32_vec().unwrap();
    let k_pos = c.get("k_pos").as_i32_vec().unwrap();

    let mut rt = Runtime::new(default_artifact_dir()).unwrap();
    let (out, lse) = rt.attn_block("attn_full_tiny", &q, &k, &v, &q_pos, &k_pos).unwrap();

    let eo = tens(&c, "expect_out", &[sq, h, d]);
    let el = tens(&c, "expect_lse", &[h, sq]);
    assert!(out.allclose(&eo, 1e-4), "out diff={}", out.max_abs_diff(&eo));
    assert!(lse.allclose(&el, 1e-4), "lse diff={}", lse.max_abs_diff(&el));
}

#[test]
fn merge_tiny_matches_python_oracle_and_full_attention() {
    let Some(c) = load_case("merge_tiny.json") else {
        return;
    };
    let (sq, h, d) = (
        c.get("sq").as_usize().unwrap(),
        c.get("heads").as_usize().unwrap(),
        c.get("head_dim").as_usize().unwrap(),
    );
    let oa = tens(&c, "out_a", &[sq, h, d]);
    let la = tens(&c, "lse_a", &[h, sq]);
    let ob = tens(&c, "out_b", &[sq, h, d]);
    let lb = tens(&c, "lse_b", &[h, sq]);

    let mut rt = Runtime::new(default_artifact_dir()).unwrap();
    let (om, lm) = rt.merge("merge_tiny", &oa, &la, &ob, &lb).unwrap();

    let eo = tens(&c, "expect_out", &[sq, h, d]);
    let el = tens(&c, "expect_lse", &[h, sq]);
    assert!(om.allclose(&eo, 1e-4), "merge out diff={}", om.max_abs_diff(&eo));
    assert!(lm.allclose(&el, 1e-4), "merge lse diff={}", lm.max_abs_diff(&el));

    // merged partials == full attention (the TokenRing invariant end-to-end)
    let fo = tens(&c, "expect_full_out", &[sq, h, d]);
    let fl = tens(&c, "expect_full_lse", &[h, sq]);
    assert!(om.allclose(&fo, 1e-3), "full out diff={}", om.max_abs_diff(&fo));
    assert!(lm.allclose(&fl, 1e-3), "full lse diff={}", lm.max_abs_diff(&fl));
}

#[test]
fn native_attention_matches_pjrt_artifact() {
    // The native Rust backend and the PJRT artifact must be interchangeable.
    let Some(c) = load_case("attn_causal_tiny.json") else {
        return;
    };
    let (sq, skv) = (c.get("sq").as_usize().unwrap(), c.get("skv").as_usize().unwrap());
    let (h, d) = (c.get("heads").as_usize().unwrap(), c.get("head_dim").as_usize().unwrap());
    let q = tens(&c, "q", &[sq, h, d]);
    let k = tens(&c, "k", &[skv, h, d]);
    let v = tens(&c, "v", &[skv, h, d]);
    let q_pos = c.get("q_pos").as_i32_vec().unwrap();
    let k_pos = c.get("k_pos").as_i32_vec().unwrap();

    let (no, nl) =
        tokenring::attention::attention_block(&q, &k, &v, &q_pos, &k_pos, true, None);
    let eo = tens(&c, "expect_out", &[sq, h, d]);
    let el = tens(&c, "expect_lse", &[h, sq]);
    assert!(no.allclose(&eo, 1e-4), "native out diff={}", no.max_abs_diff(&eo));
    assert!(nl.allclose(&el, 1e-4), "native lse diff={}", nl.max_abs_diff(&el));
}

#[test]
fn runtime_rejects_shape_mismatch() {
    if !default_artifact_dir().join("manifest.json").exists() {
        return;
    }
    let mut rt = Runtime::new(default_artifact_dir()).unwrap();
    let bad = Tensor::zeros(&[2, 2, 2]);
    let pos = vec![0i32; 64];
    let err = rt
        .execute(
            "attn_causal_tiny",
            &[
                ArgValue::F32(&bad),
                ArgValue::F32(&bad),
                ArgValue::F32(&bad),
                ArgValue::I32(&pos),
                ArgValue::I32(&pos),
            ],
        )
        .unwrap_err();
    assert!(err.to_string().contains("shape"), "unexpected error: {err}");
}
