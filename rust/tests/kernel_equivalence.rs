//! Property tests: the tiled mask-classified kernel must reproduce the
//! scalar reference kernel (kept in-crate as `attention_block_reference`)
//! across tile-boundary shapes, GQA groups, padding keys, fully-masked
//! tiles, and zigzag position orders — and the threaded engines must keep
//! matching `full_attention` with the new kernel under both recording
//! modes. A per-dtype sweep repeats the kernel comparison with the KV
//! operands packed to bf16/f16 (documented roundoff tolerances), and a
//! serve-level check pins the continuous batcher's f32 digests while
//! bounding the packed-storage drift.

mod common;

use common::{prop_shapes, rand_t};
use tokenring::attention::{
    attention_block, attention_block_reference, full_attention, MASK_VALUE, KV_TILE, Q_TILE,
};
use tokenring::engine::backend::BackendSpec;
use tokenring::engine::{run_hybrid, run_ring_attention, run_token_ring, EngineOpts};
use tokenring::parallelism::partition::Partition;
use tokenring::tensor::{Dtype, Tensor};
use tokenring::util::rng::Rng;

#[allow(clippy::too_many_arguments)]
fn check_pair(
    rng: &mut Rng,
    sq: usize,
    skv: usize,
    h: usize,
    h_kv: usize,
    d: usize,
    qp: &[i32],
    kp: &[i32],
    causal: bool,
    label: &str,
) {
    let q = rand_t(rng, &[sq, h, d]);
    let k = rand_t(rng, &[skv, h_kv, d]);
    let v = rand_t(rng, &[skv, h_kv, d]);
    let (out, lse) = attention_block(&q, &k, &v, qp, kp, causal, None);
    let (eo, el) = attention_block_reference(&q, &k, &v, qp, kp, causal, None);
    assert!(
        out.allclose(&eo, 1e-5),
        "{label}: out diff={}",
        out.max_abs_diff(&eo)
    );
    assert!(
        lse.allclose(&el, 1e-4),
        "{label}: lse diff={}",
        lse.max_abs_diff(&el)
    );
}

#[test]
fn tiled_vs_reference_random_shapes() {
    // Randomized sweep across shapes that straddle Q_TILE/KV_TILE
    // boundaries, with query offsets placing the causal frontier inside,
    // before, and after the key range. Seed 7002/40 trials reproduces the
    // historical inline generator bit-for-bit (see common::prop_shapes).
    let mut rng = Rng::new(7001);
    for (trial, s) in prop_shapes(7002, 40).iter().enumerate() {
        check_pair(
            &mut rng,
            s.sq,
            s.skv,
            s.h,
            s.h_kv,
            s.d,
            &s.q_positions(),
            &s.k_positions(),
            s.causal,
            &s.label(trial),
        );
    }
}

#[test]
fn tiled_vs_reference_exact_tile_boundaries() {
    let mut rng = Rng::new(7010);
    for &sq in &[Q_TILE - 1, Q_TILE, Q_TILE + 1, 2 * Q_TILE, 2 * Q_TILE + 1] {
        for &skv in &[KV_TILE - 1, KV_TILE, KV_TILE + 1, 2 * KV_TILE] {
            let qp: Vec<i32> = ((skv / 2) as i32..(skv / 2 + sq) as i32).collect();
            let kp: Vec<i32> = (0..skv as i32).collect();
            check_pair(&mut rng, sq, skv, 2, 2, 8, &qp, &kp, true, &format!("sq={sq} skv={skv}"));
        }
    }
}

#[test]
fn tiled_vs_reference_padding_and_masked_tiles() {
    let mut rng = Rng::new(7020);
    // padding tail crossing a KV tile boundary
    let (sq, skv) = (17, KV_TILE + 21);
    let qp: Vec<i32> = (skv as i32..(skv + sq) as i32).collect();
    let mut kp: Vec<i32> = (0..skv as i32).collect();
    kp[KV_TILE - 3..].fill(-1);
    check_pair(&mut rng, sq, skv, 4, 2, 8, &qp, &kp, true, "padding tail");
    // interior padding stripe (forces Mixed tiles on both sides)
    let mut kp2: Vec<i32> = (0..skv as i32).collect();
    kp2[10..30].fill(-1);
    check_pair(&mut rng, sq, skv, 2, 1, 8, &qp, &kp2, false, "padding stripe");
    // entire key range in the future: all tiles FullyMasked, exact zeros
    let q = rand_t(&mut rng, &[33, 2, 8]);
    let k = rand_t(&mut rng, &[70, 2, 8]);
    let qp3: Vec<i32> = (0..33).collect();
    let kp3: Vec<i32> = (5000..5070).collect();
    let (out, lse) = attention_block(&q, &k, &k, &qp3, &kp3, true, None);
    assert!(out.data().iter().all(|&x| x == 0.0));
    assert!(lse.data().iter().all(|&x| x == MASK_VALUE));
}

#[test]
fn tiled_vs_reference_zigzag_shard_positions() {
    // the position order zigzag partitions hand to device actors:
    // chunk i and chunk 2N-1-i back to back, per device
    let mut rng = Rng::new(7030);
    let n = 4usize;
    let total = 8 * n * 7; // not tile-aligned per shard
    let chunk = total / (2 * n);
    for dev in 0..n {
        let mut pos: Vec<i32> = Vec::new();
        pos.extend((dev * chunk) as i32..((dev + 1) * chunk) as i32);
        let hi = 2 * n - 1 - dev;
        pos.extend((hi * chunk) as i32..((hi + 1) * chunk) as i32);
        let s = pos.len();
        check_pair(&mut rng, s, s, 2, 2, 8, &pos, &pos, true, &format!("zigzag dev={dev}"));
    }
}

/// Per-dtype output tolerance for the packed-KV sweep.
///
/// f32 KV is bit-identical storage, so the only divergence from the
/// scalar reference is streaming-softmax rounding: 1e-6 on outputs, 1e-5
/// on LSE. The packed formats add one encode roundoff per KV element
/// before any arithmetic; with O(1)-scale inputs and d <= 16 the score
/// perturbation stays well inside 48 unit roundoffs (bf16 ~ 9.4e-2,
/// f16 ~ 1.2e-2), the same bound BENCH_engine.json's kv_precision rows
/// assert in CI.
fn dtype_tols(dt: Dtype) -> (f32, f32) {
    if dt.is_packed() {
        let atol = 48.0 * dt.unit_roundoff();
        (atol, atol)
    } else {
        (1e-6, 1e-5)
    }
}

#[allow(clippy::too_many_arguments)]
fn check_pair_dtype(
    rng: &mut Rng,
    dt: Dtype,
    sq: usize,
    skv: usize,
    h: usize,
    h_kv: usize,
    d: usize,
    qp: &[i32],
    kp: &[i32],
    causal: bool,
    label: &str,
) {
    // sigma 0.5 keeps raw scores O(1), so the 1e-6 f32 bound measures
    // summation-order rounding (SIMD tree vs serial) rather than
    // exp()-amplified score noise at large |score|
    let scaled = |rng: &mut Rng, shape: &[usize]| -> Tensor {
        Tensor::new(shape, rng.normal_vec(shape.iter().product(), 0.5))
    };
    let q = scaled(rng, &[sq, h, d]);
    let k = scaled(rng, &[skv, h_kv, d]);
    let v = scaled(rng, &[skv, h_kv, d]);
    let (kd, vd) = (k.encode(dt), v.encode(dt));
    assert_eq!(kd.dtype(), dt, "{label}: encode dtype");
    let (out, lse) = attention_block(&q, &kd, &vd, qp, kp, causal, None);
    // oracle reads the unpacked f32 operands — the packed kernel path must
    // land within the storage format's roundoff of the exact answer
    let (eo, el) = attention_block_reference(&q, &k, &v, qp, kp, causal, None);
    let (out_tol, lse_tol) = dtype_tols(dt);
    assert!(
        out.allclose(&eo, out_tol),
        "{label} dtype={}: out diff={} > {out_tol}",
        dt.name(),
        out.max_abs_diff(&eo)
    );
    assert!(
        lse.allclose(&el, lse_tol),
        "{label} dtype={}: lse diff={} > {lse_tol}",
        dt.name(),
        lse.max_abs_diff(&el)
    );
}

#[test]
fn per_dtype_sweep_tile_boundaries_and_gqa() {
    // the ISSUE-9 acceptance sweep: every storage dtype, over shapes that
    // straddle Q_TILE/KV_TILE boundaries and GQA group layouts
    for dt in [Dtype::F32, Dtype::Bf16, Dtype::F16] {
        let mut rng = Rng::new(7060);
        for &sq in &[Q_TILE - 1, Q_TILE, 2 * Q_TILE + 1] {
            for &skv in &[KV_TILE - 1, KV_TILE, 2 * KV_TILE] {
                let qp: Vec<i32> = ((skv / 2) as i32..(skv / 2 + sq) as i32).collect();
                let kp: Vec<i32> = (0..skv as i32).collect();
                for &(h, h_kv) in &[(2usize, 2usize), (4, 2), (4, 1)] {
                    check_pair_dtype(
                        &mut rng,
                        dt,
                        sq,
                        skv,
                        h,
                        h_kv,
                        12, // off-lane-width head dim: exercises the SIMD tail
                        &qp,
                        &kp,
                        true,
                        &format!("sq={sq} skv={skv} h={h}/{h_kv}"),
                    );
                }
            }
        }
    }
}

#[test]
fn per_dtype_sweep_zigzag_shard_positions() {
    // packed KV under the zigzag position order device actors see
    for dt in [Dtype::F32, Dtype::Bf16, Dtype::F16] {
        let mut rng = Rng::new(7070);
        let n = 4usize;
        let chunk = 8 * n * 7 / (2 * n);
        for dev in 0..n {
            let mut pos: Vec<i32> = Vec::new();
            pos.extend((dev * chunk) as i32..((dev + 1) * chunk) as i32);
            let hi = 2 * n - 1 - dev;
            pos.extend((hi * chunk) as i32..((hi + 1) * chunk) as i32);
            let s = pos.len();
            check_pair_dtype(
                &mut rng,
                dt,
                s,
                s,
                4,
                2,
                8,
                &pos,
                &pos,
                true,
                &format!("zigzag dev={dev}"),
            );
        }
    }
}

#[test]
fn serve_digests_pinned_for_f32_and_bounded_for_packed() {
    // Serve-level acceptance: declaring kv_dtype=f32 is a no-op (encode
    // passes f32 deltas through as storage-sharing clones, so digests are
    // bit-identical to the default path), and packed storage moves every
    // digest by no more than the format's roundoff allows.
    use tokenring::scheduler::{serve_continuous, ContinuousServeOpts, RequestStatus};
    use tokenring::workload::{Priority, Request};

    let requests: Vec<Request> = (0..4)
        .map(|id| Request {
            id,
            seq_len: 32 + 16 * (id % 2),
            arrival: 0.0,
            decode_tokens: 4,
            priority: Priority::Standard,
            prefix: None,
        })
        .collect();
    let opts = ContinuousServeOpts {
        devices: 2,
        heads: 2,
        head_dim: 8,
        chunk: 16,
        seed: 42,
        ..Default::default()
    };
    let serve = |dt: Dtype| {
        let mut o = opts.clone();
        o.engine.kv_dtype = dt;
        let rep = serve_continuous(&requests, &o).unwrap();
        for r in &rep.requests {
            assert_eq!(r.status, RequestStatus::Completed, "dtype={} req {}", dt.name(), r.id);
            assert!(r.output_digest > 0.0, "dtype={} req {} digest", dt.name(), r.id);
        }
        rep.requests.iter().map(|r| r.output_digest).collect::<Vec<f64>>()
    };
    let baseline = serve(Dtype::F32);
    let default_path = {
        let rep = serve_continuous(&requests, &opts).unwrap();
        rep.requests.iter().map(|r| r.output_digest).collect::<Vec<f64>>()
    };
    assert_eq!(baseline, default_path, "explicit f32 must be bit-identical to the default");
    for dt in [Dtype::Bf16, Dtype::F16] {
        let got = serve(dt);
        for (i, (a, b)) in got.iter().zip(&baseline).enumerate() {
            let rel = (a - b).abs() / b.abs().max(1.0);
            assert!(
                rel <= 64.0 * f64::from(dt.unit_roundoff()),
                "dtype={} request {i}: digest {a} drifted {rel:.3e} from f32 {b}",
                dt.name()
            );
        }
    }
}

#[test]
fn engines_match_oracle_with_and_without_recording() {
    // the kernel rewrite must be invisible to the engine oracle tests in
    // both recording modes (record=true exercises the timeline path that
    // wraps every kernel call)
    let mut rng = Rng::new(7040);
    let (seq, h, d) = (64usize, 2usize, 16usize);
    let q = rand_t(&mut rng, &[seq, h, d]);
    let k = rand_t(&mut rng, &[seq, h, d]);
    let v = rand_t(&mut rng, &[seq, h, d]);
    let (eo, el) = full_attention(&q, &k, &v, true);
    for record in [false, true] {
        let opts = EngineOpts {
            causal: true,
            partition: Partition::Zigzag,
            backend: BackendSpec::Native,
            record,
            ..Default::default()
        };
        for (name, got) in [
            ("token_ring", run_token_ring(&q, &k, &v, 4, &opts).unwrap()),
            ("ring_attention", run_ring_attention(&q, &k, &v, 4, &opts).unwrap()),
            ("hybrid", run_hybrid(&q, &k, &v, 2, 2, &opts).unwrap()),
        ] {
            assert!(
                got.out.allclose(&eo, 1e-4),
                "{name} record={record} out diff={}",
                got.out.max_abs_diff(&eo)
            );
            assert!(
                got.lse.allclose(&el, 1e-3),
                "{name} record={record} lse diff={}",
                got.lse.max_abs_diff(&el)
            );
        }
    }
}

#[test]
fn cloned_tensor_shares_storage_until_mutation() {
    // public-API view of the zero-copy send contract
    let mut rng = Rng::new(7050);
    let t = rand_t(&mut rng, &[16, 2, 8]);
    let sent = t.clone();
    assert!(sent.shares_storage(&t));
    assert_eq!(t.storage_refcount(), 2);
    let mut mutated = sent.clone();
    mutated.data_mut()[0] += 1.0;
    assert!(!mutated.shares_storage(&t), "CoW must detach on write");
    assert!(sent.shares_storage(&t), "reader clones stay shared");
}
